//! Elastic fleet membership: the epoch-phased coordinator state machine.
//!
//! The paper's per-worker decode-and-prediction chains assume predictor
//! state that lives as long as the worker — but at production scale churn
//! is the steady state, not a fault. This module promotes the master from
//! a fixed-fleet round loop to an explicit phase machine (the Psyche
//! coordinator design): the run is divided into *fleet epochs* of
//! `admit_at` rounds, and the member set only changes at epoch boundaries:
//!
//! ```text
//!   WaitingForMembers(min) ──(≥ min at launch)──▶ Warmup (epoch 0)
//!        WaitingForMembers ──(boundary with ≥ min parked)──▶ Training
//!        Warmup ──(first boundary)──▶ Training
//!        Training ──(members < min after a tick)──▶ Holding
//!        Holding ──(boundary where quorum returns)──▶ Training
//! ```
//!
//! **Holding** is the below-`min_workers` parking state (ROADMAP elastic
//! follow-up c): rather than training on a sub-quorum remnant — or
//! erroring out, as the pre-elastic engine did — the boundary *demotes*
//! every remaining member back to the pending set and the engine idles,
//! still serving roster/sync broadcasts so parked and newly dialing
//! workers keep a live view of the fleet. The demoted workers' chains are
//! dropped exactly like an eviction's; when enough workers are parked for
//! quorum (`members + pending >= min_workers`), the next tick re-admits
//! them with fresh chains and training resumes.
//!
//! * A worker that asks to join mid-epoch **parks in a pending set** and is
//!   admitted at the next boundary (never mid-epoch — chains are stateful
//!   delay lines, so admission must align with a chain-reset point).
//! * Admission rebuilds the worker's decode chain from scratch on *both*
//!   sides (the chain-reset contract, DESIGN.md §7): momentum-EF state
//!   tolerates the perturbation (arXiv 2305.15155), and per-block chains of
//!   blockwise schemes reset together (arXiv 1905.10936).
//! * Data assignments are re-derived per epoch from `(epoch, worker_id)`
//!   and the member set ([`bitmap_rank`] + [`assignment_seed`]), so the
//!   partition re-balances as the fleet grows or shrinks.
//! * The member set rides the broadcast header ([`Frame::sync_w`]): every
//!   elastic broadcast carries the membership bitmap in `payload_bits`, and
//!   boundary broadcasts ship the **absolute** parameter vector so parked
//!   and late-joining workers re-enter bit-exactly in sync.
//!
//! The machine itself is pure (no I/O, no clocks): transports feed it
//! Join/Leave/Timeout events and the round engine ticks it at boundaries,
//! which is what makes it property-testable over arbitrary event sequences
//! (`tests/prop_coordinator.rs`).

use std::collections::BTreeSet;

use anyhow::Result;

use crate::comm::{Frame, FrameKind};

/// Elastic fleets are capped at 64 workers: the member set travels in the
/// `u64 payload_bits` header field of every elastic broadcast. Larger
/// fleets need a side-channel membership payload (ROADMAP).
pub const MAX_FLEET: usize = 64;

/// `[membership]` configuration: the fleet may shrink to `min_workers` and
/// grow to `max_workers`; admission/eviction happen every `admit_at`
/// rounds (the fleet-epoch length).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MembershipSpec {
    pub min_workers: usize,
    pub max_workers: usize,
    /// Rounds per fleet epoch; boundaries at `t % admit_at == 0`.
    pub admit_at: u64,
}

impl MembershipSpec {
    /// Validate against the fabric's provisioned slot count.
    pub fn validate(&self, slots: usize) -> Result<()> {
        anyhow::ensure!(self.min_workers >= 1, "[membership] min_workers must be >= 1");
        anyhow::ensure!(
            self.min_workers <= self.max_workers,
            "[membership] min_workers {} > max_workers {}",
            self.min_workers,
            self.max_workers
        );
        anyhow::ensure!(
            self.max_workers <= slots,
            "[membership] max_workers {} exceeds the fabric's {slots} worker slots",
            self.max_workers
        );
        anyhow::ensure!(
            slots <= MAX_FLEET,
            "elastic membership supports at most {MAX_FLEET} worker slots (bitmap header), got {slots}"
        );
        anyhow::ensure!(self.admit_at >= 1, "[membership] admit_at must be >= 1");
        Ok(())
    }
}

/// Coordinator phase (the Psyche tick states).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Not enough members to start; the run rendezvous holds here.
    WaitingForMembers,
    /// Epoch 0: the initial fleet's first epoch.
    Warmup,
    /// Steady state: boundaries admit/evict between epochs.
    Training,
    /// Below `min_workers` after a boundary: every remaining member was
    /// demoted to the pending set and training is parked. The machine
    /// serves broadcasts but runs no training rounds until a boundary
    /// finds quorum parked again (`members + pending >= min_workers`).
    Holding,
}

/// What changed at one epoch boundary.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BoundaryDiff {
    /// The epoch just entered.
    pub epoch: u64,
    /// Workers admitted at this boundary (fresh chains on both sides).
    pub admitted: Vec<usize>,
    /// Workers evicted at this boundary (chains dropped; rebuilt fresh if
    /// they are ever re-admitted).
    pub evicted: Vec<usize>,
}

/// The pure membership state machine. Events ([`Membership::on_join`] /
/// [`Membership::on_leave`] / [`Membership::on_timeout`]) only stage
/// changes; the member set itself mutates exclusively in
/// [`Membership::tick`] — the never-admits-mid-epoch invariant.
#[derive(Clone, Debug)]
pub struct Membership {
    spec: MembershipSpec,
    slots: usize,
    phase: Phase,
    epoch: u64,
    members: BTreeSet<usize>,
    /// joined mid-epoch; admitted (oldest wid first) at the next boundary
    pending: BTreeSet<usize>,
    /// announced departure (or timed out) mid-epoch; evicted at the boundary
    leaving: BTreeSet<usize>,
}

impl Membership {
    pub fn new(spec: MembershipSpec, slots: usize, initial: &[usize]) -> Result<Self> {
        spec.validate(slots)?;
        let members: BTreeSet<usize> = initial.iter().copied().collect();
        anyhow::ensure!(
            members.len() == initial.len(),
            "duplicate worker id in the initial member set"
        );
        for &w in &members {
            anyhow::ensure!(w < slots, "initial member {w} out of range (slots = {slots})");
        }
        anyhow::ensure!(
            members.len() <= spec.max_workers,
            "{} initial members exceed max_workers {}",
            members.len(),
            spec.max_workers
        );
        if members.len() >= spec.min_workers {
            return Ok(Self {
                spec,
                slots,
                phase: Phase::Warmup,
                epoch: 0,
                members,
                pending: BTreeSet::new(),
                leaving: BTreeSet::new(),
            });
        }
        // sub-quorum launch: park the initial set as pending — members is
        // empty until a boundary finds quorum (same contract as Holding,
        // so no training round ever runs on a below-min fleet)
        Ok(Self {
            spec,
            slots,
            phase: Phase::WaitingForMembers,
            epoch: 0,
            members: BTreeSet::new(),
            pending: members,
            leaving: BTreeSet::new(),
        })
    }

    pub fn spec(&self) -> &MembershipSpec {
        &self.spec
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    /// The current fleet epoch (0 until the first tick).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn is_member(&self, wid: usize) -> bool {
        self.members.contains(&wid)
    }

    pub fn n_members(&self) -> usize {
        self.members.len()
    }

    /// Current members in ascending worker-id order.
    pub fn members(&self) -> Vec<usize> {
        self.members.iter().copied().collect()
    }

    /// Member set as the broadcast-header bitmap (bit w = worker w).
    pub fn bitmap(&self) -> u64 {
        let mut b = 0u64;
        for &w in &self.members {
            b |= 1u64 << w;
        }
        b
    }

    /// Worker `wid` asks to join: park it until the next boundary.
    /// Idempotent; a current member's join request is ignored.
    pub fn on_join(&mut self, wid: usize) {
        if wid < self.slots && !self.members.contains(&wid) {
            self.pending.insert(wid);
        }
    }

    /// Worker `wid` announced departure: evicted at the next boundary.
    pub fn on_leave(&mut self, wid: usize) {
        if self.members.contains(&wid) {
            self.leaving.insert(wid);
        }
        self.pending.remove(&wid);
    }

    /// Transport-level loss of `wid` (no clean leave): same staging as a
    /// leave, and any pending join is cancelled.
    pub fn on_timeout(&mut self, wid: usize) {
        self.on_leave(wid);
    }

    /// Cross an epoch boundary: evict leavers, admit pending joins (oldest
    /// worker id first) up to `max_workers`, advance the phase. The only
    /// place the member set changes.
    ///
    /// Below-min handling: if the surviving members fall short of
    /// `min_workers`, admission is *quorum-gated* — pending joins are
    /// admitted only when they restore quorum all at once
    /// (`members + pending >= min_workers`), so the machine never trains
    /// on a sub-quorum fleet even transiently. Failing that, the remnant
    /// members are demoted back to pending (their chains dropped like any
    /// eviction's) and the phase parks in [`Phase::Holding`].
    pub fn tick(&mut self) -> BoundaryDiff {
        let mut evicted: Vec<usize> = self.leaving.iter().copied().collect();
        for w in &evicted {
            self.members.remove(w);
        }
        self.leaving.clear();
        let below_min = self.members.len() < self.spec.min_workers;
        let quorum = self.members.len() + self.pending.len() >= self.spec.min_workers;
        let mut admitted = Vec::new();
        if !below_min || quorum {
            while self.members.len() < self.spec.max_workers {
                match self.pending.iter().next().copied() {
                    Some(w) => {
                        self.pending.remove(&w);
                        self.members.insert(w);
                        admitted.push(w);
                    }
                    None => break,
                }
            }
        }
        self.epoch += 1;
        if self.members.len() < self.spec.min_workers {
            // demote the remnant to pending: they re-enter with fresh
            // chains at the boundary where quorum returns
            let demoted: Vec<usize> = self.members.iter().copied().collect();
            for &w in &demoted {
                self.pending.insert(w);
            }
            self.members.clear();
            evicted.extend(demoted);
            self.phase = Phase::Holding;
        } else {
            self.phase = Phase::Training;
        }
        BoundaryDiff { epoch: self.epoch, admitted, evicted }
    }
}

/// Partition position of `wid` within a member bitmap: `(rank, n_members)`
/// with rank = number of set bits below `wid`. `None` for non-members.
/// This is what re-keys the data partition when the fleet changes: the
/// strided shard owner becomes the member *rank*, not the worker id.
pub fn bitmap_rank(bitmap: u64, wid: usize) -> Option<(usize, usize)> {
    if wid >= MAX_FLEET || bitmap & (1u64 << wid) == 0 {
        return None;
    }
    let below = bitmap & ((1u64 << wid) - 1);
    Some((below.count_ones() as usize, bitmap.count_ones() as usize))
}

/// Visit-order seed for worker `wid`'s shard in fleet epoch `fleet_epoch`:
/// identical `(seed, epoch, worker_id)` inputs re-derive identical
/// assignments on every replica (the determinism the property tests pin).
/// Epoch 0 maps to the static-fleet seed so an unchurned run stays
/// bit-identical to a run without membership at all.
pub fn assignment_seed(seed: u64, fleet_epoch: u64, wid: usize) -> u64 {
    if fleet_epoch == 0 {
        return seed;
    }
    seed ^ fleet_epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (wid as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)
}

/// Master-side membership plan, carried in `MasterSpec`.
#[derive(Clone, Debug)]
pub struct MembershipPlan {
    pub spec: MembershipSpec,
    /// Worker ids admitted for epoch 0 (the launch rendezvous set).
    pub initial: Vec<usize>,
    /// Liveness deadline for the elastic engine: an expected member silent
    /// for this long is staged for eviction at the next boundary
    /// (`[fabric] dead_grace`, the same clock the transports use).
    pub dead_grace: std::time::Duration,
}

/// Worker-side membership plan, carried in `WorkerSpec`: which fleet
/// epochs this worker *seeks* membership in. Admission is still the
/// master's call — the broadcast bitmap is authoritative; the plan only
/// drives when the worker sends Join/Leave control frames.
#[derive(Clone, Debug)]
pub struct WorkerMembership {
    /// Rounds per fleet epoch (must match the master's `admit_at`).
    pub admit_at: u64,
    /// Half-open fleet-epoch spans `[a, b)` of sought membership.
    pub epochs: Vec<(u64, u64)>,
}

impl WorkerMembership {
    /// Seek membership in every epoch (the static-capable default).
    pub fn always(admit_at: u64) -> Self {
        Self { admit_at, epochs: vec![(0, u64::MAX)] }
    }

    pub fn wants(&self, epoch: u64) -> bool {
        self.epochs.iter().any(|&(a, b)| epoch >= a && epoch < b)
    }

    pub fn epoch_of(&self, round: u64) -> u64 {
        round / self.admit_at.max(1)
    }
}

/// Engine-side fleet bookkeeping: the state machine plus the per-round
/// *expected set* — which slots owe the master a frame this round. The
/// expected set is exactly the roster the previous broadcast reached
/// ([`crate::comm::MasterTransport::broadcast_roster`]): a worker only
/// starts sending after it has seen a broadcast, so roster-lag can never
/// deadlock the wait loop.
pub(crate) struct ElasticFleet {
    pub(crate) membership: Membership,
    pub(crate) admit_at: u64,
    pub(crate) expected: Vec<bool>,
    /// First round each slot was expected to send (staleness accounting
    /// for late joiners).
    pub(crate) start_round: Vec<u64>,
    /// Slots past their liveness deadline: masked out of the expected set
    /// (the engine stops waiting on them) while their staged eviction
    /// rides to the next boundary. A wedged slot's decode chain is
    /// condemned — frames it queued while wedged are discarded, never
    /// folded — and the mask clears only once the slot is a non-member
    /// producing frames again (a fresh admission with a fresh chain).
    pub(crate) wedged: Vec<bool>,
}

impl ElasticFleet {
    pub(crate) fn new(plan: &MembershipPlan, slots: usize) -> Result<Self> {
        let membership = Membership::new(plan.spec, slots, &plan.initial)?;
        Ok(Self {
            membership,
            admit_at: plan.spec.admit_at,
            expected: vec![false; slots],
            start_round: vec![0; slots],
            wedged: vec![false; slots],
        })
    }

    /// Slot `wid` blew its liveness deadline: stop expecting frames from
    /// it this round and stage its eviction for the next boundary.
    pub(crate) fn mark_wedged(&mut self, wid: usize) {
        self.wedged[wid] = true;
        self.expected[wid] = false;
        self.membership.on_timeout(wid);
    }

    pub(crate) fn is_wedged(&self, wid: usize) -> bool {
        self.wedged[wid]
    }

    /// A formerly wedged slot produced frames again *after* its boundary
    /// eviction completed: clear the mask so a re-join can be admitted.
    pub(crate) fn revive(&mut self, wid: usize) {
        self.wedged[wid] = false;
    }

    /// Route one arriving control frame into the state machine — the one
    /// admission path every fabric backend shares.
    pub(crate) fn observe(&mut self, wid: usize, frame: &Frame) {
        match frame.kind {
            FrameKind::Join => self.membership.on_join(wid),
            FrameKind::Leave => self.membership.on_leave(wid),
            _ => {}
        }
    }

    /// Adopt the roster a broadcast reached as the expected set for
    /// `next_round`, recording first-expected rounds for new slots.
    /// Wedged slots are masked out: a broadcast may still reach their
    /// (alive but silent) socket, but the engine must not wait on them.
    pub(crate) fn set_expected(&mut self, roster: Vec<bool>, next_round: u64) {
        for (wid, &now) in roster.iter().enumerate() {
            let eff = now && !self.wedged[wid];
            if eff && !self.expected[wid] {
                self.start_round[wid] = next_round;
            }
            self.expected[wid] = eff;
        }
    }

    pub(crate) fn expected_count(&self) -> usize {
        self.expected.iter().filter(|&&e| e).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(min: usize, max: usize, admit_at: u64) -> MembershipSpec {
        MembershipSpec { min_workers: min, max_workers: max, admit_at }
    }

    #[test]
    fn spec_validation() {
        assert!(spec(1, 4, 8).validate(4).is_ok());
        assert!(spec(0, 4, 8).validate(4).is_err(), "min 0");
        assert!(spec(5, 4, 8).validate(8).is_err(), "min > max");
        assert!(spec(1, 9, 8).validate(8).is_err(), "max > slots");
        assert!(spec(1, 4, 0).validate(4).is_err(), "admit_at 0");
        assert!(spec(1, 65, 8).validate(65).is_err(), "beyond bitmap");
    }

    #[test]
    fn phases_walk_the_psyche_diagram() {
        // sub-quorum launch parks the initial set: no member trains below min
        let m = Membership::new(spec(2, 4, 8), 4, &[0]).unwrap();
        assert_eq!(m.phase(), Phase::WaitingForMembers);
        assert_eq!(m.n_members(), 0, "below-min initial set parks as pending");
        let mut m = Membership::new(spec(2, 4, 8), 4, &[0, 1]).unwrap();
        assert_eq!(m.phase(), Phase::Warmup);
        assert_eq!(m.epoch(), 0);
        // steady boundary: no changes, Warmup -> Training
        let d = m.tick();
        assert_eq!(d, BoundaryDiff { epoch: 1, admitted: vec![], evicted: vec![] });
        assert_eq!(m.phase(), Phase::Training);
        // shrink below min: the survivor is demoted to pending and the
        // machine parks in Holding rather than training sub-quorum
        m.on_leave(1);
        assert_eq!(m.n_members(), 2, "leave stages; eviction waits for the tick");
        let d = m.tick();
        assert_eq!(d.evicted, vec![1, 0], "leaver evicted, remnant demoted");
        assert_eq!(m.phase(), Phase::Holding);
        assert_eq!(m.n_members(), 0);
        // a lone boundary without quorum stays parked
        let d = m.tick();
        assert!(d.admitted.is_empty() && d.evicted.is_empty());
        assert_eq!(m.phase(), Phase::Holding);
        // quorum returns (demoted 0 still parked + rejoining 1): both are
        // re-admitted together at the boundary
        m.on_join(1);
        assert_eq!(m.n_members(), 0, "join parks; admission waits for the tick");
        let d = m.tick();
        assert_eq!(d.admitted, vec![0, 1]);
        assert_eq!(m.phase(), Phase::Training);
    }

    #[test]
    fn admission_is_capped_and_ordered_by_worker_id() {
        let mut m = Membership::new(spec(1, 3, 4), 8, &[0, 1]).unwrap();
        m.on_join(7);
        m.on_join(4);
        m.on_join(2);
        let d = m.tick();
        // one free slot (max 3): lowest pending wid wins; others stay parked
        assert_eq!(d.admitted, vec![2]);
        assert_eq!(m.members(), vec![0, 1, 2]);
        m.on_leave(0);
        let d = m.tick();
        assert_eq!(d.evicted, vec![0]);
        assert_eq!(d.admitted, vec![4]);
        assert_eq!(m.members(), vec![1, 2, 4]);
    }

    #[test]
    fn events_are_idempotent_and_member_aware() {
        let mut m = Membership::new(spec(1, 4, 4), 4, &[0, 1]).unwrap();
        m.on_join(0); // already a member: ignored
        m.on_leave(3); // not a member: ignored
        m.on_join(2);
        m.on_join(2);
        m.on_timeout(2); // cancels the pending join
        let d = m.tick();
        assert!(d.admitted.is_empty());
        assert!(d.evicted.is_empty());
        assert_eq!(m.members(), vec![0, 1]);
    }

    #[test]
    fn bitmap_and_ranks() {
        let mut m = Membership::new(spec(1, 4, 4), 8, &[1, 3, 6]).unwrap();
        assert_eq!(m.bitmap(), 0b0100_1010);
        assert_eq!(bitmap_rank(m.bitmap(), 1), Some((0, 3)));
        assert_eq!(bitmap_rank(m.bitmap(), 3), Some((1, 3)));
        assert_eq!(bitmap_rank(m.bitmap(), 6), Some((2, 3)));
        assert_eq!(bitmap_rank(m.bitmap(), 0), None);
        assert_eq!(bitmap_rank(0, 70), None);
        m.on_join(0);
        m.tick();
        assert_eq!(bitmap_rank(m.bitmap(), 1), Some((1, 4)), "ranks shift on growth");
    }

    #[test]
    fn assignment_seed_is_static_at_epoch_zero_and_keyed_after() {
        assert_eq!(assignment_seed(42, 0, 3), 42);
        assert_ne!(assignment_seed(42, 1, 3), 42);
        assert_eq!(assignment_seed(42, 5, 3), assignment_seed(42, 5, 3));
        assert_ne!(assignment_seed(42, 5, 3), assignment_seed(42, 5, 4));
        assert_ne!(assignment_seed(42, 5, 3), assignment_seed(42, 6, 3));
    }

    #[test]
    fn worker_plan_spans_are_half_open() {
        let p = WorkerMembership { admit_at: 4, epochs: vec![(0, 1), (3, u64::MAX)] };
        assert!(p.wants(0));
        assert!(!p.wants(1));
        assert!(!p.wants(2));
        assert!(p.wants(3));
        assert!(p.wants(100));
        assert_eq!(p.epoch_of(0), 0);
        assert_eq!(p.epoch_of(3), 0);
        assert_eq!(p.epoch_of(4), 1);
        assert!(WorkerMembership::always(4).wants(7));
    }
}
