//! Single-process launcher: datasets + fabric + one thread per worker +
//! the master inline. The `[fabric]` config picks the transport — the
//! in-process channel fabric or real TCP sockets on 127.0.0.1 — plus
//! pipelining, aggregation mode and fault injection; the Worker/Master
//! loops are identical either way (multi-process deployments reuse them
//! via cli::master_serve / worker_connect).
//!
//! The one front door is [`Launcher`]: a builder over [`ExperimentConfig`]
//! that every launch path — config file, CLI overrides, hand-assembled
//! configs in tests — funnels through, so the composition gate
//! ([`crate::config::compose::validate`]) and the single/multi-run fork
//! cannot be bypassed. `run_training` / `run_training_with_manifest` remain
//! as thin compatibility wrappers over it.

use std::net::SocketAddr;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::comm::fault::{FaultInjector, FaultPolicy, FaultStats, ReconnectBackoff};
use crate::comm::tcp::{TcpMaster, TcpWorker};
use crate::comm::{
    channel_fabric, MasterTransport, ReactorMaster, RunWorker, ShardMap, ShardedWorkerEndpoint,
    WorkerTransport,
};
use crate::config::{
    AdaptiveCfg, ChaosKind, ExperimentConfig, FabricSpec, IoBackend, MembershipCfg, ShardsSpec,
    TraceCfg, TransportKind,
};
use crate::data::{Dataset, MarkovCorpus, Shard, SynthImages};
use crate::metrics::registry::{Counter, Gauge, Meter, Registry};
use crate::metrics::trace::{TraceEvent, TraceKind, TraceRing, Tracer};
use crate::metrics::{CommStats, ObsReport, RunPoint};
use crate::model::{Manifest, ModelKind};
use crate::runtime::{ModelExec, Runtime};
use crate::scheme::Scheme;
use crate::util::timer::PhaseTimes;

use super::master::{evaluate, EvalFn, MasterLoop, MasterObs, MasterReport, MasterSpec, TestStream};
use super::multirun::{run_multi, HostedRun};
use super::shard::ShardedMasterLoop;
use super::worker::{WorkerLoop, WorkerObs, WorkerSpec, WorkerSummary};

/// Aggregated result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub points: Vec<RunPoint>,
    pub final_test_acc: f64,
    pub final_test_loss: f64,
    pub bits_per_component: f64,
    pub compression_ratio: f64,
    pub simulated_comm_secs: f64,
    /// Per-block (name, bits/component) for blockwise schemes (empty
    /// otherwise) — mirrors `CommStats::block_rates`.
    pub block_rates: Vec<(String, f64)>,
    pub worker_phases: PhaseTimes,
    /// per-round mean over workers of (1/d)‖e_t‖²
    pub e_mse_trace: Vec<f64>,
    /// per-round mean over workers of ‖u_t‖²
    pub u_norm_trace: Vec<f64>,
    pub workers: Vec<WorkerSummary>,
    /// Full communication accounting (payload bits, per-block rates,
    /// fabric-health counters, comm-phase timings).
    pub comm: CommStats,
}

impl TrainReport {
    /// Mean per-iteration worker compute time split by phase — Fig. 1's
    /// bars plus the fabric phases this engine adds (send/wait).
    pub fn phase_means(&self) -> Vec<(String, f64)> {
        ["gradient", "compress", "encode", "send", "wait", "apply"]
            .iter()
            .map(|p| (p.to_string(), self.worker_phases.mean(p)))
            .collect()
    }
}

/// What [`Launcher::serve`] hands back: one [`TrainReport`] per hosted run
/// in declaration order (a failed run is an `Err` slot — its siblings ran
/// to completion regardless), plus the worst cross-run round skew the
/// multi-tenant sweep observed (always 0 for a single run).
pub struct LaunchReport {
    pub runs: Vec<Result<TrainReport>>,
    pub max_round_skew: u64,
    /// Drained trace stream + final metrics snapshot when `[trace]` was
    /// enabled; `None` (no registry, no ring, no overhead) otherwise.
    pub trace: Option<ObsReport>,
}

impl LaunchReport {
    /// Unwrap the single-run case (the wrappers' return shape).
    pub fn into_single(mut self) -> Result<TrainReport> {
        anyhow::ensure!(
            self.runs.len() == 1,
            "launch hosted {} runs; read LaunchReport.runs instead",
            self.runs.len()
        );
        self.runs.pop().expect("one run")
    }
}

/// The unified launch front door: build over an [`ExperimentConfig`],
/// override individual facets, then [`serve`](Self::serve).
///
/// ```no_run
/// # use tempo::config::ExperimentConfig;
/// # use tempo::coordinator::launch::Launcher;
/// # fn main() -> tempo::Result<()> {
/// let cfg = ExperimentConfig::from_toml_str("name = \"demo\"\nworkers = 2\nsteps = 4\n")?;
/// let report = Launcher::new(cfg).runs(2).serve()?;
/// assert_eq!(report.runs.len(), 2);
/// # Ok(()) }
/// ```
///
/// Every facet setter writes back into the config, so `serve` always
/// re-validates the *composed* result through the one gate
/// ([`crate::config::compose::validate`]) — an unsupported pair is refused
/// identically whether it came from a TOML file, a CLI flag, or a builder
/// call. `runs(1)` (the default) is a structural bypass of the multi-tenant
/// demux: the single-run path is byte-for-byte the classic launcher.
pub struct Launcher {
    cfg: ExperimentConfig,
    manifest: Option<Manifest>,
}

impl Launcher {
    pub fn new(cfg: ExperimentConfig) -> Self {
        Self { cfg, manifest: None }
    }

    /// Use a pre-loaded model manifest instead of [`Manifest::load_default`].
    pub fn manifest(mut self, manifest: Manifest) -> Self {
        self.manifest = Some(manifest);
        self
    }

    /// Master-side I/O engine (thread-per-peer or single-thread reactor).
    pub fn io(mut self, io: IoBackend) -> Self {
        self.cfg.fabric.io = io;
        self
    }

    /// Fabric transport (in-process channels or TCP on 127.0.0.1).
    pub fn transport(mut self, transport: TransportKind) -> Self {
        self.cfg.fabric.transport = transport;
        self
    }

    /// Master shard count (1 = the plain unsharded master).
    pub fn shards(mut self, count: usize) -> Self {
        self.cfg.shards.count = count;
        self
    }

    /// Elastic fleet membership (DESIGN.md §7/§10).
    pub fn membership(mut self, membership: MembershipCfg) -> Self {
        self.cfg.membership = Some(membership);
        self
    }

    /// Adaptive per-block rate control (DESIGN.md §8).
    pub fn adaptive(mut self, adaptive: AdaptiveCfg) -> Self {
        self.cfg.adaptive = Some(adaptive);
        self
    }

    /// Host `count` independent runs on one master process (DESIGN.md §11).
    pub fn runs(mut self, count: usize) -> Self {
        self.cfg.runs.count = count;
        self
    }

    /// Observability: `[trace]` switch, event-ring size, JSONL sink.
    pub fn trace(mut self, trace: TraceCfg) -> Self {
        self.cfg.trace = trace;
        self
    }

    /// Validate the composed config and run it to completion in-process.
    pub fn serve(self) -> Result<LaunchReport> {
        self.cfg.validate()?;
        let manifest = match self.manifest {
            Some(m) => m,
            None => Manifest::load_default()?,
        };
        let obs = LaunchObs::new(&self.cfg.trace);
        let mut report = if self.cfg.runs.is_multi() {
            serve_multi(&self.cfg, &manifest, &obs)?
        } else {
            let report = serve_single(&self.cfg, &manifest, &obs)?;
            LaunchReport { runs: vec![Ok(report)], max_round_skew: 0, trace: None }
        };
        report.trace = obs.finish(report.max_round_skew)?;
        Ok(report)
    }
}

/// Launcher-owned observability wiring: one [`Registry`] and one bounded
/// [`TraceRing`] shared by every loop of the launch (DESIGN.md §12). With
/// `[trace]` off (the default) this is structurally `None` — no registry,
/// no ring, and every handle passed downstream is an off shell, so the
/// uninstrumented run is bit- and alloc-identical to one built before the
/// observability layer existed.
struct LaunchObs {
    on: Option<LaunchObsInner>,
}

struct LaunchObsInner {
    registry: Registry,
    meter: Meter,
    ring: Arc<TraceRing>,
    tracer: Tracer,
    /// `multirun.round_skew_max`: set once from the sweep's report.
    round_skew: Gauge,
    /// `chaos.backoff_attempts`: re-dial attempts by chaos-cycled workers.
    backoff: Counter,
    path: Option<String>,
}

/// Register the launcher-level instrument vocabulary on `meter`. The one
/// registration site for these names: [`LaunchObs`] calls it live, the doc
/// gate (`tests/doc_metrics.rs`) calls it to enumerate.
pub fn launch_instruments(meter: &Meter) -> (Gauge, Counter) {
    let round_skew = meter.gauge(
        "multirun.round_skew_max",
        "rounds",
        "worst cross-run round skew at any multi-tenant sweep boundary",
    );
    let backoff = meter.counter(
        "chaos.backoff_attempts",
        "attempts",
        "re-dial attempts made by chaos-cycled workers during backoff",
    );
    (round_skew, backoff)
}

impl LaunchObs {
    fn new(cfg: &TraceCfg) -> Self {
        if !cfg.enabled {
            return Self { on: None };
        }
        let registry = Registry::new();
        let meter = registry.meter();
        let ring = TraceRing::new(cfg.ring);
        let tracer = Tracer::on(Arc::clone(&ring));
        let (round_skew, backoff) = launch_instruments(&meter);
        Self {
            on: Some(LaunchObsInner {
                registry,
                meter,
                ring,
                tracer,
                round_skew,
                backoff,
                path: cfg.path.clone(),
            }),
        }
    }

    /// Round-engine observer stamped with `run_id` (off shell when off).
    fn master_obs(&self, run_id: u16) -> MasterObs {
        match &self.on {
            Some(o) => MasterObs::new(&o.meter, o.tracer.clone(), run_id),
            None => MasterObs::off(),
        }
    }

    /// Worker-phase observer. One instrument set is shared by the whole
    /// fleet — per-round phase histograms aggregate across workers.
    fn worker_obs(&self) -> WorkerObs {
        match &self.on {
            Some(o) => WorkerObs::new(&o.meter),
            None => WorkerObs::off(),
        }
    }

    /// Wire the `comm.*` instruments into a run's master endpoint(s).
    fn attach(&self, master: &mut MasterEndpoints) {
        if let Some(o) = &self.on {
            match master {
                MasterEndpoints::Plain(t) => t.attach_meter(&o.meter),
                MasterEndpoints::Sharded(_, ts) => {
                    for t in ts.iter_mut() {
                        t.attach_meter(&o.meter);
                    }
                }
            }
        }
    }

    fn attach_boxed(&self, master: &mut Box<dyn MasterTransport>) {
        if let Some(o) = &self.on {
            master.attach_meter(&o.meter);
        }
    }

    /// Handles a chaos-cycled worker thread carries across its backoff.
    fn chaos_handles(&self) -> (Tracer, Counter) {
        match &self.on {
            Some(o) => (o.tracer.clone(), o.backoff.clone()),
            None => (Tracer::off(), Counter::off()),
        }
    }

    /// Stamp a configured chaos injection (emitted at launch, when the
    /// schedule is armed — `round` is the configured trigger round).
    fn chaos_inject(&self, worker: u32, kind: ChaosKind, round: u64) {
        if let Some(o) = &self.on {
            let value = match kind {
                ChaosKind::Wedge => 0,
                ChaosKind::Crash => 1,
                ChaosKind::HalfOpen => 2,
            };
            o.tracer.emit(TraceEvent {
                kind: TraceKind::ChaosInject,
                run_id: 0,
                round,
                epoch: 0,
                worker,
                value,
            });
        }
    }

    /// Close out the launch: publish the sweep's skew, drain the ring,
    /// write the JSONL sink if one was configured, snapshot the registry.
    fn finish(self, max_round_skew: u64) -> Result<Option<ObsReport>> {
        let Some(o) = self.on else { return Ok(None) };
        o.round_skew.set(max_round_skew as f64);
        let (events, dropped) = o.ring.drain();
        if let Some(path) = &o.path {
            let mut out = String::with_capacity(events.len() * 64 + 1);
            for ev in &events {
                out.push_str(&ev.to_jsonl());
                out.push('\n');
            }
            std::fs::write(path, out).with_context(|| format!("write trace stream {path}"))?;
        }
        Ok(Some(ObsReport { events, dropped, snapshot: o.registry.snapshot() }))
    }
}

/// Build the training dataset for a model kind.
pub fn build_dataset(
    kind: ModelKind,
    entry: &crate::model::ModelEntry,
    cfg: &ExperimentConfig,
) -> Arc<dyn Dataset> {
    match kind {
        ModelKind::Classifier => Arc::new(SynthImages::new(
            entry.classes.max(2),
            cfg.train_len,
            cfg.test_len,
            cfg.seed,
            cfg.noise,
        )),
        ModelKind::Lm => Arc::new(MarkovCorpus::new(
            entry.vocab,
            entry.seq,
            cfg.train_len,
            cfg.seed,
        )),
    }
}

/// What [`build_fabric`] hands back: the master endpoint, one endpoint per
/// worker (fault injection already wrapped in), and the per-worker fault
/// counters to harvest after the run.
pub type Fabric =
    (Box<dyn MasterTransport>, Vec<Box<dyn WorkerTransport>>, Vec<Arc<Mutex<FaultStats>>>);

/// Per-worker endpoints plus the master endpoint for the configured
/// transport. Boxed so the two fabrics share every downstream code path.
pub fn build_fabric(fabric: &FabricSpec, n: usize) -> Result<Fabric> {
    Ok(build_fabric_addr(fabric, n)?.0)
}

/// [`build_fabric`] plus the master's bound address (TCP fabrics only) —
/// what the chaos cycle driver re-dials after a crash leg.
pub fn build_fabric_addr(fabric: &FabricSpec, n: usize) -> Result<(Fabric, Option<SocketAddr>)> {
    let mut workers: Vec<Box<dyn WorkerTransport>> = Vec::with_capacity(n);
    let mut master_addr = None;
    let master: Box<dyn MasterTransport> = match fabric.transport {
        TransportKind::Channel => {
            let (m, ws) = channel_fabric(n);
            for w in ws {
                workers.push(Box::new(w));
            }
            Box::new(m)
        }
        TransportKind::Tcp => {
            // bind port 0, dial every worker (handshakes queue in the
            // backlog), then accept them all
            let listener =
                std::net::TcpListener::bind("127.0.0.1:0").context("bind fabric socket")?;
            let addr = listener.local_addr()?;
            master_addr = Some(addr);
            for wid in 0..n {
                workers.push(Box::new(
                    TcpWorker::connect(addr, wid as u32)
                        .with_context(|| format!("worker {wid}: dial fabric"))?,
                ));
            }
            master_from_listener(fabric, listener, n)?
        }
    };
    let mut fault_stats = Vec::new();
    if fabric.has_faults() {
        workers = wrap_faults(fabric, workers, &mut fault_stats);
    }
    Ok(((master, workers, fault_stats), master_addr))
}

/// Accept `n` workers on a bound listener with the configured master-side
/// I/O engine — the one TCP-master construction path the in-process
/// launcher and `tempo master-serve` (per shard) share, so backend
/// selection cannot drift between deployments.
pub fn master_from_listener(
    fabric: &FabricSpec,
    listener: std::net::TcpListener,
    n: usize,
) -> Result<Box<dyn MasterTransport>> {
    let grace = fabric.dead_grace_duration();
    Ok(match fabric.io {
        IoBackend::Threads => Box::new(TcpMaster::from_listener_graced(listener, n, n, grace)?),
        IoBackend::Reactor => Box::new(ReactorMaster::from_listener_graced(
            listener,
            n,
            n,
            fabric.reactor_queue_bound(),
            grace,
        )?),
    })
}

fn wrap_faults(
    fabric: &FabricSpec,
    workers: Vec<Box<dyn WorkerTransport>>,
    fault_stats: &mut Vec<Arc<Mutex<FaultStats>>>,
) -> Vec<Box<dyn WorkerTransport>> {
    workers
        .into_iter()
        .enumerate()
        .map(|(wid, transport)| {
            let policy = FaultPolicy::new(
                fabric.straggler_for(wid),
                fabric.drop_prob,
                fabric.retransmit_ms,
                fabric.seed,
                wid as u32,
            )
            .with_wedge_windows(fabric.wedge_windows_for(wid));
            fault_stats.push(policy.stats());
            Box::new(FaultInjector::new(transport, policy)) as Box<dyn WorkerTransport>
        })
        .collect()
}

/// Drive one worker through a crash (or half-open) chaos cycle
/// (DESIGN.md §10): run until round `depart` and vanish — no Leave, no
/// completion marker, the socket just drops (leg 1) — sit out a seeded
/// exponential backoff, re-dial the master, and run the remaining rounds
/// as a fresh incarnation that fences off its own stale seat (leg 2,
/// `rejoin`). Half-open additionally holds a cloned write half across the
/// backoff, so the master sees pure silence instead of EOF: its liveness
/// deadline, not the socket, is what must evict us. Backoff pacing is tied
/// to `dead_grace` (base = grace/40, cap = grace — the documented
/// 50 ms → 2 s default at the default grace), so shrinking the deadline in
/// tests shrinks the whole cycle with it.
#[allow(clippy::too_many_arguments)]
fn run_chaos_cycle(
    spec: WorkerSpec,
    mut transport: Box<dyn WorkerTransport>,
    shard: Shard,
    shard2: Shard,
    dataset: Arc<dyn Dataset>,
    runtime: &Runtime,
    kind: ChaosKind,
    depart: u64,
    seed: u64,
    dead_grace: Duration,
    addr: SocketAddr,
    tracer: Tracer,
    backoff_ctr: Counter,
    wobs: WorkerObs,
) -> Result<WorkerSummary> {
    let wid = spec.worker_id;
    let hold = match kind {
        ChaosKind::HalfOpen => transport.split_sender().ok(),
        _ => None,
    };
    let mut spec1 = spec.clone();
    spec1.depart_at = Some(depart);
    let s1 = WorkerLoop::new(spec1, transport, shard, Arc::clone(&dataset))
        .with_observer(wobs.clone())
        .run(runtime)?;
    // leg 1's socket dropped with the loop above: a crash presents EOF/RST
    // to the master; half-open keeps `hold`'s fd alive so the master sees
    // nothing at all until the re-dial below supersedes the connection
    let mut backoff = ReconnectBackoff::with_pacing(
        seed,
        wid,
        (dead_grace / 40).max(Duration::from_millis(1)),
        dead_grace.max(Duration::from_millis(50)),
    );
    let t2 = loop {
        std::thread::sleep(backoff.next_delay());
        // stamped per dial attempt; `round` is 0 — a backing-off worker is
        // outside the round schedule, its attempt index is the `value`
        backoff_ctr.inc();
        tracer.emit(TraceEvent {
            kind: TraceKind::Backoff,
            run_id: 0,
            round: 0,
            epoch: 0,
            worker: wid,
            value: u64::from(backoff.attempts()),
        });
        match TcpWorker::connect(addr, wid) {
            Ok(t) => break t,
            Err(e) => anyhow::ensure!(
                backoff.attempts() < 12,
                "worker {wid}: chaos re-dial failed after {} attempts: {e:#}",
                backoff.attempts()
            ),
        }
    };
    drop(hold);
    let mut spec2 = spec;
    spec2.rejoin = true;
    let s2 = WorkerLoop::new(spec2, t2, shard2, dataset).with_observer(wobs).run(runtime)?;
    Ok(merge_chaos_legs(s1, s2))
}

/// Merge a chaos worker's two run legs into the one summary the launcher
/// reports: traces concatenate (leg 1 covers rounds up to the crash, leg 2
/// the rounds after re-admission), phase clocks and skip counts add, and
/// the loss tail is leg 2's (the post-recovery trajectory is the one that
/// matters) unless it never trained.
fn merge_chaos_legs(mut a: WorkerSummary, b: WorkerSummary) -> WorkerSummary {
    a.phases.merge(&b.phases);
    a.e_mse_trace.extend(b.e_mse_trace);
    a.u_norm_trace.extend(b.u_norm_trace);
    a.skipped_rounds += b.skipped_rounds;
    if b.mean_loss_last_quarter != 0.0 {
        a.mean_loss_last_quarter = b.mean_loss_last_quarter;
    }
    a.rounds = a.rounds.max(b.rounds);
    a.pipelined = a.pipelined || b.pipelined;
    a
}

/// What [`build_sharded_fabric`] hands back: one master endpoint per
/// shard, one [`ShardedWorkerEndpoint`] per worker, and the fault counters.
pub type ShardedFabric =
    (Vec<Box<dyn MasterTransport>>, Vec<Box<dyn WorkerTransport>>, Vec<Arc<Mutex<FaultStats>>>);

/// Sharded fabric: one plain fabric per shard (channel or TCP, same as
/// [`build_fabric`]), transposed into per-worker [`ShardedWorkerEndpoint`]s
/// that scatter updates by block and gather the per-shard broadcasts.
/// Fault injection wraps the *sharded* endpoint, so a straggler/drop
/// scenario delays each logical update once — every shard sees the same
/// degraded schedule, exactly like the unsharded run.
pub fn build_sharded_fabric(
    fabric: &FabricSpec,
    n: usize,
    map: &Arc<ShardMap>,
) -> Result<ShardedFabric> {
    let n_shards = map.n_shards();
    // inner fabrics carry no fault injection of their own (chaos wedges
    // included — the sharded endpoint wrap below swallows each logical
    // update once, so every shard sees the same wedged schedule)
    let clean = FabricSpec {
        straggler_ms: Vec::new(),
        drop_prob: 0.0,
        chaos: Vec::new(),
        ..fabric.clone()
    };
    let mut masters = Vec::with_capacity(n_shards);
    let mut per_worker: Vec<Vec<Box<dyn WorkerTransport>>> =
        (0..n).map(|_| Vec::with_capacity(n_shards)).collect();
    for shard in 0..n_shards {
        let (master, workers, _) = build_fabric(&clean, n)
            .with_context(|| format!("shard {shard} fabric"))?;
        masters.push(master);
        for (w, t) in workers.into_iter().enumerate() {
            per_worker[w].push(t);
        }
    }
    let mut workers_out: Vec<Box<dyn WorkerTransport>> = Vec::with_capacity(n);
    for parts in per_worker {
        workers_out.push(Box::new(ShardedWorkerEndpoint::new(Arc::clone(map), parts)?));
    }
    let mut fault_stats = Vec::new();
    if fabric.has_faults() {
        workers_out = wrap_faults(fabric, workers_out, &mut fault_stats);
    }
    Ok((masters, workers_out, fault_stats))
}

/// Master-side endpoints for a run: the plain single master, or one
/// endpoint per shard.
pub enum MasterEndpoints {
    Plain(Box<dyn MasterTransport>),
    Sharded(Arc<ShardMap>, Vec<Box<dyn MasterTransport>>),
}

impl MasterEndpoints {
    /// Drive the headless round loop on whichever side this is.
    pub fn run_headless(self, spec: MasterSpec, d: usize) -> Result<MasterReport> {
        match self {
            MasterEndpoints::Plain(t) => MasterLoop::new(spec, t).run_headless(d),
            MasterEndpoints::Sharded(map, t) => {
                ShardedMasterLoop::new(spec, map, t)?.run_headless(d)
            }
        }
    }
}

/// What [`build_run_fabric`] hands back.
pub type RunFabric = (MasterEndpoints, Vec<Box<dyn WorkerTransport>>, Vec<Arc<Mutex<FaultStats>>>);

/// Build the fabric for a run with the configured master shard count
/// (`count = 1` = the plain unsharded fabric) — the one front door the
/// launcher, the experiment drivers and the integration tests share, so
/// sharded and plain construction cannot drift apart.
pub fn build_run_fabric(
    fabric: &FabricSpec,
    n: usize,
    shards: &ShardsSpec,
    scheme: &Scheme,
    d: usize,
) -> Result<RunFabric> {
    Ok(build_run_fabric_addr(fabric, n, shards, scheme, d)?.0)
}

/// [`build_run_fabric`] plus the master's bound address (plain TCP fabrics
/// only) — what the chaos cycle driver re-dials after a crash leg.
pub fn build_run_fabric_addr(
    fabric: &FabricSpec,
    n: usize,
    shards: &ShardsSpec,
    scheme: &Scheme,
    d: usize,
) -> Result<(RunFabric, Option<SocketAddr>)> {
    if shards.is_sharded() {
        let layout = scheme.block_layout(d)?;
        let map = shards.build_map(&layout).context("invalid [shards] for this scheme")?;
        let map = Arc::new(map);
        let (masters, workers, stats) = build_sharded_fabric(fabric, n, &map)?;
        Ok(((MasterEndpoints::Sharded(map, masters), workers, stats), None))
    } else {
        let ((master, workers, stats), addr) = build_fabric_addr(fabric, n)?;
        Ok(((MasterEndpoints::Plain(master), workers, stats), addr))
    }
}

/// Model-backed sharded master run: the per-shard engines run headless
/// (evaluation needs the assembled vector), and the gathered final `w` is
/// scored once against the PJRT model — the sharded counterpart of
/// [`MasterLoop::run`].
pub fn run_sharded_master(
    spec: MasterSpec,
    map: Arc<ShardMap>,
    transports: Vec<Box<dyn MasterTransport>>,
    runtime: &Runtime,
) -> Result<MasterReport> {
    let model = ModelExec::load(runtime, &spec.model).context("sharded master: load model")?;
    let w0 = runtime.manifest.load_init(&model.entry)?;
    let test = TestStream::for_model(&model.entry, &spec);
    let mut eval = |w: &[f32], batches: usize, salt: u64| -> Result<(f64, f64)> {
        evaluate(&model, w, &test, batches, salt)
    };
    ShardedMasterLoop::new(spec, map, transports)?.run_with_w(w0, Some(&mut eval))
}

/// Run a full experiment in-process: n worker threads + the master on the
/// calling thread. Deterministic given cfg.seed (and, with faults off,
/// bit-identical across transports).
///
/// Compatibility wrapper over [`Launcher`] — new code should build a
/// `Launcher` directly (it exposes the multi-run report this flattens).
pub fn run_training(cfg: &ExperimentConfig) -> Result<TrainReport> {
    Launcher::new(cfg.clone()).serve()?.into_single()
}

/// Compatibility wrapper over [`Launcher::manifest`] — see [`run_training`].
pub fn run_training_with_manifest(
    cfg: &ExperimentConfig,
    manifest: &Manifest,
) -> Result<TrainReport> {
    Launcher::new(cfg.clone()).manifest(manifest.clone()).serve()?.into_single()
}

/// The classic single-run launcher ([`Launcher::serve`] with `runs = 1`):
/// n worker threads + the master on the calling thread.
fn serve_single(
    cfg: &ExperimentConfig,
    manifest: &Manifest,
    obs: &LaunchObs,
) -> Result<TrainReport> {
    let entry = manifest.model(&cfg.model)?.clone();
    let d = entry.d;
    let scheme = cfg.scheme.to_scheme()?;
    // bind-check once up front so scheme errors surface before threads spawn
    scheme.worker(d).context("invalid scheme for this model dimension")?;
    let dataset = build_dataset(entry.kind, &entry, cfg);
    let schedule = cfg.schedule();

    let ((mut master_side, workers_tx, fault_stats), master_addr) =
        build_run_fabric_addr(&cfg.fabric, cfg.workers, &cfg.shards, &scheme, d)?;
    obs.attach(&mut master_side);
    let worker_obs = obs.worker_obs();

    let mut handles = Vec::with_capacity(cfg.workers);
    for (wid, transport) in workers_tx.into_iter().enumerate() {
        let spec = WorkerSpec {
            worker_id: wid as u32,
            model: cfg.model.clone(),
            scheme: scheme.clone(),
            backend: cfg.backend,
            schedule,
            steps: cfg.steps,
            seed: cfg.seed,
            clip_norm: (cfg.clip_norm > 0.0).then_some(cfg.clip_norm),
            pipelined: cfg.fabric.pipelined,
            absent: cfg.fabric.absent_for(wid),
            depart_at: None,
            rejoin: false,
            membership: cfg.membership.as_ref().map(|m| m.worker_plan()),
            adaptive: cfg.adaptive.is_some(),
        };
        let shard = Shard::new(wid, cfg.workers, cfg.train_len, entry.batch, cfg.seed);
        let dataset = Arc::clone(&dataset);
        let manifest = manifest.clone();
        // wedge chaos rides the fault injector (wrap_faults); a crash or
        // half-open entry routes this worker through the two-leg cycle
        for &(kind, at, _) in &cfg.fabric.chaos_for(wid) {
            obs.chaos_inject(wid as u32, kind, at);
        }
        let cycle = cfg
            .fabric
            .chaos_for(wid)
            .into_iter()
            .find(|&(k, _, _)| k != ChaosKind::Wedge);
        let wobs = worker_obs.clone();
        match cycle {
            None => handles.push(std::thread::spawn(move || -> Result<WorkerSummary> {
                // PJRT objects are !Send: each worker builds its own runtime
                let runtime = Runtime::new(manifest)?;
                WorkerLoop::new(spec, transport, shard, dataset).with_observer(wobs).run(&runtime)
            })),
            Some((kind, depart, _)) => {
                let addr = master_addr.context(
                    "chaos crash/half-open needs the plain (unsharded) tcp fabric",
                )?;
                let seed = cfg.seed;
                let grace = cfg.fabric.dead_grace_duration();
                let shard2 = Shard::new(wid, cfg.workers, cfg.train_len, entry.batch, cfg.seed);
                let (tracer, backoff_ctr) = obs.chaos_handles();
                handles.push(std::thread::spawn(move || -> Result<WorkerSummary> {
                    let runtime = Runtime::new(manifest)?;
                    run_chaos_cycle(
                        spec, transport, shard, shard2, dataset, &runtime, kind, depart, seed,
                        grace, addr, tracer, backoff_ctr, wobs,
                    )
                }));
            }
        }
    }

    let master_spec = MasterSpec {
        model: cfg.model.clone(),
        scheme: scheme.clone(),
        schedule,
        steps: cfg.steps,
        eval_every: cfg.eval_every,
        eval_batches: cfg.eval_batches,
        seed: cfg.seed,
        samples_per_round: entry.batch * cfg.workers,
        train_len: cfg.train_len,
        data_noise: cfg.noise,
        aggregation: cfg.fabric.aggregation(),
        membership: cfg
            .membership
            .as_ref()
            .map(|m| m.master_plan(cfg.workers, cfg.fabric.dead_grace_duration()))
            .transpose()?,
        adaptive: cfg.adaptive.as_ref().map(|a| a.plan()),
    };
    let master_runtime = Runtime::new(manifest.clone())?;
    let master_result = match master_side {
        MasterEndpoints::Plain(master_tx) => MasterLoop::new(master_spec, master_tx)
            .with_observer(obs.master_obs(0))
            .run(&master_runtime)
            .context("master loop"),
        // sharded engines run with per-engine observers off: phase laps
        // are a whole-master signal, not a per-shard one (comm.* meters
        // were attached above and still count)
        MasterEndpoints::Sharded(map, masters) => {
            run_sharded_master(master_spec, map, masters, &master_runtime)
                .context("sharded master loop")
        }
    };

    // Join workers FIRST: if one of them failed, its error (e.g. "loss
    // diverged") is the root cause — the master only sees a hung channel.
    let (summaries, worker_errors) = join_workers(handles);
    let (report, summaries) = settle_run(master_result, summaries, worker_errors)?;
    Ok(assemble_train_report(cfg.workers, cfg.steps, report, summaries, &fault_stats))
}

/// Join a fleet's worker threads, splitting clean summaries from errors
/// (a panic becomes an error naming the worker).
fn join_workers(
    handles: Vec<std::thread::JoinHandle<Result<WorkerSummary>>>,
) -> (Vec<WorkerSummary>, Vec<anyhow::Error>) {
    let mut summaries = Vec::with_capacity(handles.len());
    let mut errors = Vec::new();
    for (wid, h) in handles.into_iter().enumerate() {
        match h.join() {
            Err(_) => errors.push(anyhow::anyhow!("worker {wid} panicked")),
            Ok(Err(e)) => errors.push(e.context(format!("worker {wid} failed"))),
            Ok(Ok(s)) => summaries.push(s),
        }
    }
    (summaries, errors)
}

/// Pick the error that names the root cause: a substantive worker error
/// (e.g. "loss diverged") beats secondary hung-up-channel errors on either
/// side; the master's error carries a failed worker's context if present.
fn settle_run(
    master_result: Result<MasterReport>,
    summaries: Vec<WorkerSummary>,
    mut worker_errors: Vec<anyhow::Error>,
) -> Result<(MasterReport, Vec<WorkerSummary>)> {
    if let Some(pos) = worker_errors
        .iter()
        .position(|e| !format!("{e:#}").contains("hung up"))
    {
        return Err(worker_errors.swap_remove(pos));
    }
    match master_result {
        Ok(r) => Ok((r, summaries)),
        Err(e) => Err(match worker_errors.into_iter().next() {
            Some(we) => we.context(format!("master: {e:#}")),
            None => e,
        }),
    }
}

/// Merge one run's per-worker traces, phase times, and fabric-health
/// counters with its master report — shared by the single-run and hosted
/// multi-run paths so the report shape cannot drift between them.
fn assemble_train_report(
    workers: usize,
    steps: u64,
    report: MasterReport,
    summaries: Vec<WorkerSummary>,
    fault_stats: &[Arc<Mutex<FaultStats>>],
) -> TrainReport {
    let mut phases = PhaseTimes::new();
    let steps = steps as usize;
    let mut e_mse_trace = vec![0.0f64; steps];
    let mut u_norm_trace = vec![0.0f64; steps];
    let mut comm = report.comm.clone();
    for s in &summaries {
        phases.merge(&s.phases);
        for name in ["encode", "send", "wait"] {
            comm.record_phase(name, s.phases.total(name), s.phases.count(name));
        }
        for (t, &v) in s.e_mse_trace.iter().enumerate() {
            e_mse_trace[t] += v / workers as f64;
        }
        for (t, &v) in s.u_norm_trace.iter().enumerate() {
            u_norm_trace[t] += v / workers as f64;
        }
    }
    for stats in fault_stats {
        let s = stats.lock().unwrap();
        comm.record_faults(s.retransmits, s.injected_delay_secs);
    }
    let mut points = report.points;
    for p in points.iter_mut() {
        let idx = (p.step as usize).min(steps) - 1;
        p.e_mse = e_mse_trace[idx];
    }

    TrainReport {
        points,
        final_test_acc: report.final_test_acc,
        final_test_loss: report.final_test_loss,
        bits_per_component: comm.bits_per_component(),
        compression_ratio: comm.compression_ratio(),
        simulated_comm_secs: comm.simulated_comm_secs(),
        block_rates: comm.block_rates(),
        worker_phases: phases,
        e_mse_trace,
        u_norm_trace,
        workers: summaries,
        comm,
    }
}

/// The multi-tenant launcher (DESIGN.md §11): one shared fabric with
/// `runs.count × workers` global slots, run r owning the contiguous range
/// `[r·n, (r+1)·n)`, every worker thread speaking through a
/// [`RunWorker`] stamp under its run-local id, and all R masters swept on
/// the calling thread by [`run_multi`] — zero threads beyond what R solo
/// launches of the same fleet would spawn on the worker side, and R−1
/// *fewer* master threads.
///
/// Per-run determinism: run r trains with seed `cfg.seed + r` (data,
/// shards, eval stream and master spec all derive from it), so its numbers
/// are bit-identical to a solo launch of the same config with that seed.
/// Configured fault schedules are applied per run-local worker id —
/// every hosted run sees the same degraded schedule, exactly like running
/// the faulty config R times. Crash/half-open chaos cycles are refused at
/// the compose gate (the re-dial path re-addresses a single-run seat).
fn serve_multi(
    cfg: &ExperimentConfig,
    manifest: &Manifest,
    obs: &LaunchObs,
) -> Result<LaunchReport> {
    let r_total = cfg.runs.count;
    let n = cfg.workers;
    let entry = manifest.model(&cfg.model)?.clone();
    let d = entry.d;
    let scheme = cfg.scheme.to_scheme()?;
    scheme.worker(d).context("invalid scheme for this model dimension")?;
    let schedule = cfg.schedule();

    // one shared fabric, faults stripped: injection wraps the per-run
    // endpoints below so the schedule is keyed on run-local ids
    let clean = FabricSpec {
        straggler_ms: Vec::new(),
        drop_prob: 0.0,
        chaos: Vec::new(),
        ..cfg.fabric.clone()
    };
    let (mut master, workers_tx, _) = build_fabric(&clean, r_total * n)?;
    obs.attach_boxed(&mut master);
    let worker_obs = obs.worker_obs();

    let mut datasets = Vec::with_capacity(r_total);
    for r in 0..r_total {
        let mut run_cfg = cfg.clone();
        run_cfg.seed = cfg.seed + r as u64;
        datasets.push(build_dataset(entry.kind, &entry, &run_cfg));
    }

    let mut fault_stats = Vec::new();
    let mut handles: Vec<Vec<std::thread::JoinHandle<Result<WorkerSummary>>>> =
        (0..r_total).map(|_| Vec::with_capacity(n)).collect();
    for (gid, transport) in workers_tx.into_iter().enumerate() {
        let (r, wid) = (gid / n, gid % n);
        let run_seed = cfg.seed + r as u64;
        let mut transport: Box<dyn WorkerTransport> = Box::new(RunWorker::new(transport, r as u16));
        if cfg.fabric.has_faults() {
            let policy = FaultPolicy::new(
                cfg.fabric.straggler_for(wid),
                cfg.fabric.drop_prob,
                cfg.fabric.retransmit_ms,
                cfg.fabric.seed,
                wid as u32,
            )
            .with_wedge_windows(cfg.fabric.wedge_windows_for(wid));
            fault_stats.push(policy.stats());
            transport = Box::new(FaultInjector::new(transport, policy));
        }
        let spec = WorkerSpec {
            worker_id: wid as u32,
            model: cfg.model.clone(),
            scheme: scheme.clone(),
            backend: cfg.backend,
            schedule,
            steps: cfg.steps,
            seed: run_seed,
            clip_norm: (cfg.clip_norm > 0.0).then_some(cfg.clip_norm),
            pipelined: cfg.fabric.pipelined,
            absent: cfg.fabric.absent_for(wid),
            depart_at: None,
            rejoin: false,
            membership: None,
            adaptive: false,
        };
        let shard = Shard::new(wid, n, cfg.train_len, entry.batch, run_seed);
        let dataset = Arc::clone(&datasets[r]);
        let manifest = manifest.clone();
        let wobs = worker_obs.clone();
        handles[r].push(std::thread::spawn(move || -> Result<WorkerSummary> {
            // PJRT objects are !Send: each worker builds its own runtime
            let runtime = Runtime::new(manifest)?;
            WorkerLoop::new(spec, transport, shard, dataset).with_observer(wobs).run(&runtime)
        }));
    }

    // all R masters share one runtime + model: evaluation is read-only
    let master_runtime = Runtime::new(manifest.clone())?;
    let model = ModelExec::load(&master_runtime, &cfg.model).context("multi-run: load model")?;
    let w0 = master_runtime.manifest.load_init(&model.entry)?;
    let mut tests = Vec::with_capacity(r_total);
    let mut hosted = Vec::with_capacity(r_total);
    for r in 0..r_total {
        let spec = MasterSpec {
            model: cfg.model.clone(),
            scheme: scheme.clone(),
            schedule,
            steps: cfg.steps,
            eval_every: cfg.eval_every,
            eval_batches: cfg.eval_batches,
            seed: cfg.seed + r as u64,
            samples_per_round: entry.batch * n,
            train_len: cfg.train_len,
            data_noise: cfg.noise,
            aggregation: cfg.fabric.aggregation(),
            membership: None,
            adaptive: None,
        };
        tests.push(TestStream::for_model(&entry, &spec));
        hosted.push(HostedRun {
            spec,
            init_w: w0.clone(),
            n_workers: n,
            obs: obs.master_obs(r as u16),
        });
    }
    let model = &model;
    let mut eval_fns: Vec<Box<EvalFn<'_>>> = tests
        .iter()
        .map(|test| {
            Box::new(move |w: &[f32], batches: usize, salt: u64| {
                evaluate(model, w, test, batches, salt)
            }) as Box<EvalFn<'_>>
        })
        .collect();
    let evals: Vec<Option<&mut EvalFn<'_>>> =
        eval_fns.iter_mut().map(|f| Some(&mut **f)).collect();
    let multi = run_multi(master, hosted, evals, cfg.fabric.dead_grace_duration());

    // join every fleet before propagating any master-side error: if the
    // sweep bailed structurally, dropping the transport above unblocked
    // the worker threads, and their summaries/errors are still the record
    let mut harvested = Vec::with_capacity(r_total);
    for run_handles in handles {
        harvested.push(join_workers(run_handles));
    }
    let multi = multi.context("multi-run master")?;

    let mut runs = Vec::with_capacity(r_total);
    for (r, (master_result, (summaries, worker_errors))) in
        multi.runs.into_iter().zip(harvested).enumerate()
    {
        let fs: &[Arc<Mutex<FaultStats>>] =
            if fault_stats.is_empty() { &[] } else { &fault_stats[r * n..(r + 1) * n] };
        runs.push(
            settle_run(master_result, summaries, worker_errors).map(|(report, summaries)| {
                assemble_train_report(n, cfg.steps, report, summaries, fs)
            }),
        );
    }
    Ok(LaunchReport { runs, max_round_skew: multi.max_round_skew, trace: None })
}
