//! Single-process launcher: datasets + channel fabric + one thread per
//! worker + the master inline. TCP deployments use the same Worker/Master
//! loops over `comm::tcp` endpoints (see cli::master_serve / worker_connect).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::comm::channel_fabric;
use crate::config::ExperimentConfig;
use crate::data::{Dataset, MarkovCorpus, Shard, SynthImages};
use crate::metrics::RunPoint;
use crate::model::{Manifest, ModelKind};
use crate::runtime::Runtime;
use crate::util::timer::PhaseTimes;

use super::master::{MasterLoop, MasterSpec};
use super::worker::{WorkerLoop, WorkerSpec, WorkerSummary};

/// Aggregated result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub points: Vec<RunPoint>,
    pub final_test_acc: f64,
    pub final_test_loss: f64,
    pub bits_per_component: f64,
    pub compression_ratio: f64,
    pub simulated_comm_secs: f64,
    /// Per-block (name, bits/component) for blockwise schemes (empty
    /// otherwise) — mirrors `CommStats::block_rates`.
    pub block_rates: Vec<(String, f64)>,
    pub worker_phases: PhaseTimes,
    /// per-round mean over workers of (1/d)‖e_t‖²
    pub e_mse_trace: Vec<f64>,
    /// per-round mean over workers of ‖u_t‖²
    pub u_norm_trace: Vec<f64>,
    pub workers: Vec<WorkerSummary>,
}

impl TrainReport {
    /// Mean per-iteration worker compute time split by phase — Fig. 1's bars.
    pub fn phase_means(&self) -> Vec<(String, f64)> {
        ["gradient", "compress", "encode", "apply"]
            .iter()
            .map(|p| (p.to_string(), self.worker_phases.mean(p)))
            .collect()
    }
}

/// Build the training dataset for a model kind.
pub fn build_dataset(
    kind: ModelKind,
    entry: &crate::model::ModelEntry,
    cfg: &ExperimentConfig,
) -> Arc<dyn Dataset> {
    match kind {
        ModelKind::Classifier => Arc::new(SynthImages::new(
            entry.classes.max(2),
            cfg.train_len,
            cfg.test_len,
            cfg.seed,
            cfg.noise,
        )),
        ModelKind::Lm => Arc::new(MarkovCorpus::new(
            entry.vocab,
            entry.seq,
            cfg.train_len,
            cfg.seed,
        )),
    }
}

/// Run a full experiment in-process: n worker threads + the master on the
/// calling thread. Deterministic given cfg.seed.
pub fn run_training(cfg: &ExperimentConfig) -> Result<TrainReport> {
    let manifest = Manifest::load_default()?;
    run_training_with_manifest(cfg, &manifest)
}

pub fn run_training_with_manifest(
    cfg: &ExperimentConfig,
    manifest: &Manifest,
) -> Result<TrainReport> {
    cfg.validate()?;
    let entry = manifest.model(&cfg.model)?.clone();
    let d = entry.d;
    let scheme = cfg.scheme.to_scheme()?;
    // bind-check once up front so scheme errors surface before threads spawn
    scheme.worker(d).context("invalid scheme for this model dimension")?;
    let dataset = build_dataset(entry.kind, &entry, cfg);
    let schedule = cfg.schedule();

    let (master_tx, workers_tx) = channel_fabric(cfg.workers);

    let mut handles = Vec::with_capacity(cfg.workers);
    for (wid, transport) in workers_tx.into_iter().enumerate() {
        let spec = WorkerSpec {
            worker_id: wid as u32,
            model: cfg.model.clone(),
            scheme: scheme.clone(),
            backend: cfg.backend,
            schedule,
            steps: cfg.steps,
            seed: cfg.seed,
            clip_norm: (cfg.clip_norm > 0.0).then_some(cfg.clip_norm),
        };
        let shard = Shard::new(wid, cfg.workers, cfg.train_len, entry.batch, cfg.seed);
        let dataset = Arc::clone(&dataset);
        let manifest = manifest.clone();
        handles.push(std::thread::spawn(move || -> Result<WorkerSummary> {
            // PJRT objects are !Send: each worker builds its own runtime
            let runtime = Runtime::new(manifest)?;
            WorkerLoop::new(spec, transport, shard, dataset).run(&runtime)
        }));
    }

    let master_spec = MasterSpec {
        model: cfg.model.clone(),
        scheme: scheme.clone(),
        schedule,
        steps: cfg.steps,
        eval_every: cfg.eval_every,
        eval_batches: cfg.eval_batches,
        seed: cfg.seed,
        samples_per_round: entry.batch * cfg.workers,
        train_len: cfg.train_len,
        data_noise: cfg.noise,
    };
    let master_runtime = Runtime::new(manifest.clone())?;
    let master_result = MasterLoop::new(master_spec, master_tx)
        .run(&master_runtime)
        .context("master loop");

    // Join workers FIRST: if one of them failed, its error (e.g. "loss
    // diverged") is the root cause — the master only sees a hung channel.
    let mut summaries = Vec::with_capacity(cfg.workers);
    let mut worker_errors = Vec::new();
    for (wid, h) in handles.into_iter().enumerate() {
        match h.join() {
            Err(_) => worker_errors.push(anyhow::anyhow!("worker {wid} panicked")),
            Ok(Err(e)) => worker_errors.push(e.context(format!("worker {wid} failed"))),
            Ok(Ok(s)) => summaries.push(s),
        }
    }
    // Prefer a substantive worker error (e.g. "loss diverged") over
    // secondary hung-up-channel errors on either side.
    if let Some(pos) = worker_errors
        .iter()
        .position(|e| !format!("{e:#}").contains("hung up"))
    {
        return Err(worker_errors.swap_remove(pos));
    }
    let report = match master_result {
        Ok(r) => r,
        Err(e) => {
            return Err(match worker_errors.into_iter().next() {
                Some(we) => we.context(format!("master: {e:#}")),
                None => e,
            })
        }
    };

    // merge per-worker traces and phase times
    let mut phases = PhaseTimes::new();
    let steps = cfg.steps as usize;
    let mut e_mse_trace = vec![0.0f64; steps];
    let mut u_norm_trace = vec![0.0f64; steps];
    for s in &summaries {
        phases.merge(&s.phases);
        for (t, &v) in s.e_mse_trace.iter().enumerate() {
            e_mse_trace[t] += v / cfg.workers as f64;
        }
        for (t, &v) in s.u_norm_trace.iter().enumerate() {
            u_norm_trace[t] += v / cfg.workers as f64;
        }
    }
    let mut points = report.points;
    for p in points.iter_mut() {
        let idx = (p.step as usize).min(steps) - 1;
        p.e_mse = e_mse_trace[idx];
    }

    Ok(TrainReport {
        points,
        final_test_acc: report.final_test_acc,
        final_test_loss: report.final_test_loss,
        bits_per_component: report.comm.bits_per_component(),
        compression_ratio: report.comm.compression_ratio(),
        simulated_comm_secs: report.comm.simulated_comm_secs(),
        block_rates: report.comm.block_rates(),
        worker_phases: phases,
        e_mse_trace,
        u_norm_trace,
        workers: summaries,
    })
}
