//! Multi-tenant master: R independent runs hosted on one master process,
//! one transport, one thread (DESIGN.md §11).
//!
//! Each hosted run is a complete fixed-fleet training run — its own
//! [`MasterSpec`] (scheme, schedule, aggregation mode), its own per-worker
//! decode chains, its own `w`, its own [`crate::metrics::CommStats`] —
//! demultiplexed out of the shared fabric by [`crate::comm::run`]. The
//! driver here is a cooperative round-robin sweep over steppable
//! [`RoundEngine`]s: every live engine folds exactly one round per sweep,
//! so no hosted run can get more than one round ahead of a sibling that is
//! still making progress (the fairness bound the capacity soak asserts).
//!
//! Isolation semantics:
//!
//! * a run's engine sees only its own workers (run-local ids) and
//!   broadcasts only to its own slot range — the numbers it produces are
//!   bit-identical to the same run hosted solo (pinned by
//!   `tests/multi_run.rs`);
//! * a run that *fails* (worker crash past the grace window, protocol
//!   violation) is recorded as that run's error and dropped from the
//!   sweep; sibling runs keep stepping to completion untouched;
//! * zero threads are added: the sweep runs on the caller's thread, and
//!   the shared transport is pumped cooperatively from whichever engine
//!   is waiting.

use std::time::Duration;

use anyhow::{Context, Result};

use crate::comm::run::split_runs;
use crate::comm::MasterTransport;
use crate::scheme::MasterScheme;

use super::master::{EvalFn, MasterObs, MasterReport, MasterSpec, RoundEngine};

/// One run to host: spec + initial parameters + how many of the fabric's
/// worker slots it owns. Slots are assigned contiguously in declaration
/// order: run r owns global ids `[Σ n_workers(<r), Σ n_workers(<=r))`.
pub struct HostedRun {
    pub spec: MasterSpec,
    pub init_w: Vec<f32>,
    pub n_workers: usize,
    /// Observability handle for this run's engine — [`MasterObs::off`]
    /// (the `Default`) unless the launcher wired `[trace]`. Hosted runs
    /// share one registry; each handle stamps its own run id on events.
    pub obs: MasterObs,
}

/// What the multi-tenant driver hands back: per-run outcomes (in
/// declaration order — a failed run is an `Err` slot, not a torn-down
/// process) plus the worst cross-run round skew observed at any sweep
/// boundary (0 in a healthy sweep; the capacity soak asserts the bound).
pub struct MultiRunReport {
    pub runs: Vec<Result<MasterReport>>,
    pub max_round_skew: u64,
}

/// Host every run in `runs` on `inner`, sweeping one round per run per
/// pass. `dead_grace` is the per-run fixed-fleet liveness window (how long
/// a run waits on its own lost worker before that run — and only that run
/// — fails). `evals` are per-run evaluation hooks, `None` for headless.
pub fn run_multi<M: MasterTransport>(
    inner: M,
    runs: Vec<HostedRun>,
    mut evals: Vec<Option<&mut EvalFn<'_>>>,
    dead_grace: Duration,
) -> Result<MultiRunReport> {
    let r_total = runs.len();
    anyhow::ensure!(r_total >= 1, "need at least one hosted run");
    anyhow::ensure!(
        evals.len() == r_total,
        "got {} eval hooks for {r_total} hosted runs",
        evals.len()
    );
    for (r, run) in runs.iter().enumerate() {
        // hosted runs are fixed-fleet rounds only: the elastic and
        // adaptive engines own their transport's full roster/liveness
        // surface and are not steppable (also refused at config compose)
        anyhow::ensure!(
            run.spec.membership.is_none() && run.spec.adaptive.is_none(),
            "hosted run {r}: [membership]/[adaptive] do not compose with [runs]"
        );
    }
    let sizes: Vec<usize> = runs.iter().map(|h| h.n_workers).collect();
    let ports = split_runs(inner, &sizes, dead_grace)?;

    let mut engines = Vec::with_capacity(r_total);
    for (r, (hosted, port)) in runs.into_iter().zip(ports).enumerate() {
        let d = hosted.init_w.len();
        let mut chains: Vec<Box<dyn MasterScheme>> = Vec::with_capacity(hosted.n_workers);
        for _ in 0..hosted.n_workers {
            chains.push(hosted.spec.scheme.master(d).with_context(|| format!("run {r} chains"))?);
        }
        let engine =
            RoundEngine::new(hosted.spec, 0, r as u16, chains, port, hosted.init_w, hosted.obs)
                .with_context(|| format!("hosted run {r}"))?;
        engines.push(Some(engine));
    }

    let mut results: Vec<Option<Result<MasterReport>>> = (0..r_total).map(|_| None).collect();
    let mut max_round_skew = 0u64;
    loop {
        let mut progressed = false;
        for r in 0..r_total {
            let Some(mut engine) = engines[r].take() else { continue };
            progressed = true;
            if engine.done() {
                results[r] =
                    Some(engine.finish(evals[r].as_deref_mut()).context(format!("hosted run {r}")));
                continue;
            }
            match engine.step(evals[r].as_deref_mut()) {
                Ok(()) => engines[r] = Some(engine),
                // this run is over; siblings keep their transport — the
                // demux only ever fails the port whose workers misbehaved
                Err(e) => results[r] = Some(Err(e.context(format!("hosted run {r}")))),
            }
        }
        if !progressed {
            break;
        }
        // fairness probe: at a sweep boundary every live engine has folded
        // the same number of rounds unless one was held up mid-sweep
        let live: Vec<u64> = engines
            .iter()
            .filter_map(|e| e.as_ref().map(|e| e.rounds_done()))
            .collect();
        if let (Some(&lo), Some(&hi)) = (live.iter().min(), live.iter().max()) {
            max_round_skew = max_round_skew.max(hi - lo);
        }
    }
    let runs = results.into_iter().map(|r| r.expect("every run resolved")).collect();
    Ok(MultiRunReport { runs, max_round_skew })
}
