//! Master-side loop.
//!
//! Owns: the canonical parameter vector, one decode-and-predict
//! [`MasterScheme`] per worker (paper Sec. IV-C: "the master operates a
//! separate decoding-and-prediction chain composed of a D, a P, and a delay
//! block"), the LR schedule, rate accounting (total and per block for
//! blockwise schemes) and periodic evaluation.
//!
//! Evaluation is injectable: [`MasterLoop::run`] wires the PJRT model, while
//! [`MasterLoop::run_headless`] drives the identical round loop with no
//! model at all (test/synthetic path — eval columns become NaN).

use anyhow::{Context, Result};

use crate::comm::{Frame, MasterTransport};
use crate::data::{Batch, MarkovCorpus, SynthImages};
use crate::metrics::{AccuracyMeter, CommStats, LossMeter, RunPoint};
use crate::model::ModelKind;
use crate::optim::LrSchedule;
use crate::runtime::{ModelExec, Runtime};
use crate::scheme::{MasterScheme, Scheme};
use crate::util::Timer;

/// Master configuration (plain data).
#[derive(Clone, Debug)]
pub struct MasterSpec {
    pub model: String,
    pub scheme: Scheme,
    pub schedule: LrSchedule,
    pub steps: u64,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub seed: u64,
    /// samples consumed per round across all workers (epoch bookkeeping)
    pub samples_per_round: usize,
    pub train_len: usize,
    pub data_noise: f32,
}

/// Held-out evaluation stream (kind matches the model).
pub enum TestStream {
    Images(SynthImages),
    Tokens(MarkovCorpus),
}

impl TestStream {
    pub fn for_model(entry: &crate::model::ModelEntry, spec: &MasterSpec) -> Self {
        match entry.kind {
            ModelKind::Classifier => TestStream::Images(SynthImages::new(
                entry.classes.max(2),
                spec.train_len,
                4096,
                spec.seed,
                spec.data_noise,
            )),
            ModelKind::Lm => TestStream::Tokens(MarkovCorpus::new(
                entry.vocab,
                entry.seq,
                spec.train_len,
                spec.seed,
            )),
        }
    }

    /// Deterministic held-out batch #i for the given model geometry.
    pub fn batch(&self, entry: &crate::model::ModelEntry, i: usize, salt: u64) -> Batch {
        let b = entry.batch;
        let start = (salt as usize).wrapping_mul(7919).wrapping_add(i * b);
        match self {
            TestStream::Images(ds) => ds.test_batch(start, b),
            TestStream::Tokens(ds) => {
                // windows beyond train_len are never visited by shards
                let base = ds.train_len + (start % 1_000_000);
                let mut x = vec![0i32; b * entry.seq];
                let mut y = vec![0i32; b * entry.seq];
                for row in 0..b {
                    ds.window(
                        base + row,
                        &mut x[row * entry.seq..(row + 1) * entry.seq],
                        &mut y[row * entry.seq..(row + 1) * entry.seq],
                    );
                }
                Batch::Tokens { x, y, batch: b }
            }
        }
    }
}

/// Everything the master measured during a run.
#[derive(Clone, Debug)]
pub struct MasterReport {
    pub points: Vec<RunPoint>,
    pub comm: CommStats,
    pub final_test_acc: f64,
    pub final_test_loss: f64,
    pub final_w_norm: f64,
}

/// (w, eval_batches, salt) → (test_loss, test_acc).
type EvalFn<'a> = dyn FnMut(&[f32], usize, u64) -> Result<(f64, f64)> + 'a;

/// Master loop: drives `steps` synchronous rounds over the transport.
pub struct MasterLoop<T: MasterTransport> {
    spec: MasterSpec,
    transport: T,
}

impl<T: MasterTransport> MasterLoop<T> {
    pub fn new(spec: MasterSpec, transport: T) -> Self {
        Self { spec, transport }
    }

    /// Model-backed run: PJRT evaluation on held-out batches.
    pub fn run(self, runtime: &Runtime) -> Result<MasterReport> {
        let MasterLoop { spec, transport } = self;
        let model = ModelExec::load(runtime, &spec.model).context("master: load model")?;
        let d = model.entry.d;
        let w = runtime.manifest.load_init(&model.entry)?;
        let test = TestStream::for_model(&model.entry, &spec);
        let mut eval = |w: &[f32], batches: usize, salt: u64| -> Result<(f64, f64)> {
            evaluate(&model, w, &test, batches, salt)
        };
        run_rounds(&spec, transport, w, Some(&mut eval))
    }

    /// Headless run at dimension d: no model, no evaluation (test metrics
    /// are NaN/0); parameters start at zero. The round loop — decode,
    /// per-worker chains, aggregation, broadcast, rate accounting — is the
    /// exact same code as [`Self::run`].
    pub fn run_headless(self, d: usize) -> Result<MasterReport> {
        let MasterLoop { spec, transport } = self;
        run_rounds(&spec, transport, vec![0.0f32; d], None)
    }
}

fn run_rounds<T: MasterTransport>(
    spec: &MasterSpec,
    mut transport: T,
    mut w: Vec<f32>,
    mut eval: Option<&mut EvalFn<'_>>,
) -> Result<MasterReport> {
    let d = w.len();
    let n = transport.n_workers();
    let mut chains: Vec<Box<dyn MasterScheme>> = Vec::with_capacity(n);
    for _ in 0..n {
        chains.push(spec.scheme.master(d)?);
    }
    let mut comm = CommStats::new(d);
    let mut train_loss = LossMeter::new();
    let mut points = Vec::new();
    let wall = Timer::start();

    let mut rtilde = vec![0.0f32; d];
    let mut agg = vec![0.0f32; d];

    for t in 0..spec.steps {
        let frames = transport.recv_updates()?;
        anyhow::ensure!(frames.len() == n, "round {t}: missing updates");
        agg.iter_mut().for_each(|x| *x = 0.0);
        for frame in &frames {
            anyhow::ensure!(frame.round == t, "round skew: {} vs {t}", frame.round);
            let wid = frame.worker as usize;
            anyhow::ensure!(wid < n, "bad worker id {wid}");
            comm.record_message(frame.payload_bits);
            train_loss.push(frame.loss as f64);
            let payload = frame.as_payload();
            chains[wid]
                .receive(&payload, t, &mut rtilde)
                .with_context(|| format!("round {t}: decode worker {wid}"))?;
            for bb in chains[wid].last_block_bits() {
                comm.record_block(&bb.name, bb.bits, bb.components);
            }
            let scale = 1.0 / n as f32;
            for i in 0..d {
                agg[i] += scale * rtilde[i];
            }
        }

        // broadcast the averaged r̃; workers (and we) apply w -= η·agg
        transport.broadcast(&Frame::broadcast(t, &agg))?;
        let lr = spec.schedule.lr_at(t);
        for i in 0..d {
            w[i] -= lr * agg[i];
        }

        if (t + 1) % spec.eval_every == 0 || t + 1 == spec.steps {
            let (test_loss, test_acc) = match eval.as_mut() {
                Some(f) => f(&w, spec.eval_batches, t)?,
                None => (f64::NAN, 0.0),
            };
            points.push(RunPoint {
                step: t + 1,
                epoch_equiv: ((t + 1) as f64 * spec.samples_per_round as f64)
                    / spec.train_len.max(1) as f64,
                train_loss: train_loss.smoothed(),
                test_loss,
                test_acc,
                bits_per_component: comm.bits_per_component(),
                e_mse: 0.0, // filled from worker traces by launch glue
                wall_secs: wall.elapsed_secs(),
            });
        }
    }

    let (final_test_loss, final_test_acc) = match eval.as_mut() {
        Some(f) => f(&w, (spec.eval_batches * 4).max(8), spec.steps)?,
        None => (f64::NAN, 0.0),
    };
    Ok(MasterReport {
        points,
        comm,
        final_test_acc,
        final_test_loss,
        final_w_norm: crate::tensor::norm2(&w),
    })
}

/// Mean loss / accuracy over `batches` held-out batches.
pub fn evaluate(
    model: &ModelExec,
    w: &[f32],
    test: &TestStream,
    batches: usize,
    salt: u64,
) -> Result<(f64, f64)> {
    let mut loss_sum = 0.0;
    let mut acc = AccuracyMeter::new();
    for i in 0..batches.max(1) {
        let batch = test.batch(&model.entry, i, salt);
        let (l, ncorr) = model.evaluate(w, &batch)?;
        loss_sum += l;
        acc.push(ncorr, model.eval_denominator());
    }
    Ok((loss_sum / batches.max(1) as f64, acc.accuracy()))
}
