//! Master-side round engine.
//!
//! Owns: the canonical parameter vector, one decode-and-predict
//! [`MasterScheme`] per worker (paper Sec. IV-C: "the master operates a
//! separate decoding-and-prediction chain composed of a D, a P, and a delay
//! block"), the LR schedule, rate accounting (total and per block for
//! blockwise schemes) and periodic evaluation.
//!
//! Two aggregation modes ([`AggMode`]):
//!
//! * **FullSync** — the paper's synchronous rounds: wait for one frame per
//!   worker, decode and aggregate in worker-id order (arrival order over a
//!   real fabric is nondeterministic; id order is what makes a TCP run
//!   bit-identical to a channel run).
//! * **BoundedStaleness** — proceed once `quorum` workers have a frame
//!   queued; late updates are decoded (in their own worker-round order, so
//!   every chain stays in sync) and folded into the round in which they
//!   arrive; no worker is allowed to lag more than `max_staleness` rounds.
//!   This is what keeps a straggler from serializing the whole fleet.
//!
//! Workers out of the pool send [`FrameKind::Skip`] markers (fabric churn);
//! the master aggregates over contributors only and leaves the absent
//! worker's chain untouched.
//!
//! Evaluation is injectable: [`MasterLoop::run`] wires the PJRT model, while
//! [`MasterLoop::run_headless`] drives the identical round engine with no
//! model at all (test/synthetic path — eval columns become NaN).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::comm::{Frame, FrameKind, MasterTransport, SYNC_ROUND};
use crate::coordinator::membership::{ElasticFleet, MembershipPlan, Phase};
use crate::data::{Batch, MarkovCorpus, SynthImages};
use crate::metrics::registry::{Counter, Gauge, Histogram, Meter, SECS_BUCKETS};
use crate::metrics::trace::{TraceEvent, TraceKind, Tracer, NO_WORKER};
use crate::metrics::{AccuracyMeter, CommStats, LossMeter, RunPoint};
use crate::model::ModelKind;
use crate::optim::LrSchedule;
use crate::runtime::{ModelExec, Runtime};
use crate::scheme::{AdaptivePlan, MasterScheme, RateController, Scheme};
use crate::util::Timer;

/// How the master combines worker updates each round.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AggMode {
    /// Wait for every worker every round (the paper's setting).
    #[default]
    FullSync,
    /// Aggregate whatever has arrived once `quorum` workers have a frame
    /// queued (update or skip marker — counting skips keeps a churned-out
    /// pool from deadlocking the wait); bound any worker's lag by
    /// `max_staleness` rounds.
    BoundedStaleness { max_staleness: u64, quorum: usize },
}

/// Master configuration (plain data).
#[derive(Clone, Debug)]
pub struct MasterSpec {
    pub model: String,
    pub scheme: Scheme,
    pub schedule: LrSchedule,
    pub steps: u64,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub seed: u64,
    /// samples consumed per round across all workers (epoch bookkeeping)
    pub samples_per_round: usize,
    pub train_len: usize,
    pub data_noise: f32,
    pub aggregation: AggMode,
    /// Elastic fleet membership (`[membership]` config): when set, the run
    /// goes through the epoch-phased elastic engine — workers join and
    /// leave the member set at fleet-epoch boundaries (every `admit_at`
    /// rounds) with freshly rebuilt decode chains on admission. `None`
    /// keeps the fixed-fleet engine untouched.
    pub membership: Option<MembershipPlan>,
    /// Adaptive per-block rate control (`[adaptive]` config): when set, the
    /// run goes through the scheme-epoch engine — a [`RateController`]
    /// re-rates the spec's blocks between negotiated epochs (DESIGN.md §8).
    /// `None` keeps the static engines bit-identically untouched.
    pub adaptive: Option<AdaptivePlan>,
}

/// Held-out evaluation stream (kind matches the model).
pub enum TestStream {
    Images(SynthImages),
    Tokens(MarkovCorpus),
}

impl TestStream {
    pub fn for_model(entry: &crate::model::ModelEntry, spec: &MasterSpec) -> Self {
        match entry.kind {
            ModelKind::Classifier => TestStream::Images(SynthImages::new(
                entry.classes.max(2),
                spec.train_len,
                4096,
                spec.seed,
                spec.data_noise,
            )),
            ModelKind::Lm => TestStream::Tokens(MarkovCorpus::new(
                entry.vocab,
                entry.seq,
                spec.train_len,
                spec.seed,
            )),
        }
    }

    /// Deterministic held-out batch #i for the given model geometry.
    pub fn batch(&self, entry: &crate::model::ModelEntry, i: usize, salt: u64) -> Batch {
        let b = entry.batch;
        let start = (salt as usize).wrapping_mul(7919).wrapping_add(i * b);
        match self {
            TestStream::Images(ds) => ds.test_batch(start, b),
            TestStream::Tokens(ds) => {
                // windows beyond train_len are never visited by shards
                let base = ds.train_len + (start % 1_000_000);
                let mut x = vec![0i32; b * entry.seq];
                let mut y = vec![0i32; b * entry.seq];
                for row in 0..b {
                    ds.window(
                        base + row,
                        &mut x[row * entry.seq..(row + 1) * entry.seq],
                        &mut y[row * entry.seq..(row + 1) * entry.seq],
                    );
                }
                Batch::Tokens { x, y, batch: b }
            }
        }
    }
}

/// Everything the master measured during a run.
#[derive(Clone, Debug)]
pub struct MasterReport {
    pub points: Vec<RunPoint>,
    pub comm: CommStats,
    pub final_test_acc: f64,
    pub final_test_loss: f64,
    pub final_w_norm: f64,
    /// the canonical parameter vector at the end of the run — what the
    /// deterministic-mode invariant compares bit-for-bit across fabrics
    pub final_w: Vec<f32>,
}

/// (w, eval_batches, salt) → (test_loss, test_acc).
pub type EvalFn<'a> = dyn FnMut(&[f32], usize, u64) -> Result<(f64, f64)> + 'a;

/// Master-side observability handle: the `master.*` / `fleet.*` /
/// `adaptive.*` instruments plus the structured trace emitter, threaded
/// through every round engine (docs/OBSERVABILITY.md lists the vocabulary).
///
/// [`MasterObs::off`] — the default everywhere — is a **structural
/// bypass**: the handle holds `None`, every probe below is a branch on it,
/// and the off path performs no clock reads, no atomic traffic and no
/// allocation, which is what keeps uninstrumented runs bit- and
/// alloc-identical to builds that predate observability (DESIGN.md §12).
#[derive(Clone, Default)]
pub struct MasterObs(Option<Arc<MasterObsInner>>);

struct MasterObsInner {
    /// stamped into every trace event (hosted runs: the run index)
    run_id: u16,
    tracer: Tracer,
    rounds: Counter,
    wait_secs: Histogram,
    decode_secs: Histogram,
    fold_secs: Histogram,
    broadcast_secs: Histogram,
    fleet_epoch: Gauge,
    fleet_members: Gauge,
    evictions: Counter,
    admissions: Counter,
    scheme_epoch: Gauge,
    realized_bits: Gauge,
    residual_energy: Gauge,
}

impl MasterObs {
    /// Register the master's full metric vocabulary on `meter` (idempotent
    /// by name — hosted runs share one registry) and bind trace events to
    /// `tracer`, stamped with `run_id`.
    pub fn new(meter: &Meter, tracer: Tracer, run_id: u16) -> Self {
        Self(Some(Arc::new(MasterObsInner {
            run_id,
            tracer,
            rounds: meter.counter("master.rounds", "rounds", "rounds folded and broadcast"),
            wait_secs: meter.histogram(
                "master.phase.wait_secs",
                "s",
                "per round: blocked on worker frames",
                &SECS_BUCKETS,
            ),
            decode_secs: meter.histogram(
                "master.phase.decode_secs",
                "s",
                "per round: decode chains over the round's frames",
                &SECS_BUCKETS,
            ),
            fold_secs: meter.histogram(
                "master.phase.fold_secs",
                "s",
                "per round: rate accounting plus aggregate fold",
                &SECS_BUCKETS,
            ),
            broadcast_secs: meter.histogram(
                "master.phase.broadcast_secs",
                "s",
                "per round: stage and send the broadcast",
                &SECS_BUCKETS,
            ),
            fleet_epoch: meter.gauge("fleet.epoch", "epochs", "current fleet epoch (elastic runs)"),
            fleet_members: meter.gauge(
                "fleet.members",
                "workers",
                "member-set size after the last boundary tick",
            ),
            evictions: meter.counter(
                "fleet.evictions",
                "workers",
                "members staged out (wedge or crash)",
            ),
            admissions: meter.counter(
                "fleet.admissions",
                "workers",
                "workers admitted at boundaries",
            ),
            scheme_epoch: meter.gauge(
                "adaptive.scheme_epoch",
                "epochs",
                "current negotiated scheme epoch",
            ),
            realized_bits: meter.gauge(
                "adaptive.realized_bits_per_component",
                "bits",
                "open-window realized payload rate",
            ),
            residual_energy: meter.gauge(
                "adaptive.residual_energy",
                "energy",
                "open-window folded-residual energy",
            ),
        })))
    }

    /// The structural bypass (see type docs).
    pub fn off() -> Self {
        Self(None)
    }

    pub fn is_on(&self) -> bool {
        self.0.is_some()
    }

    /// `Some(now)` only when observing — the off path never reads the
    /// clock, the on/off contract's "no extra syscalls" half.
    fn now(&self) -> Option<Instant> {
        self.0.as_ref().map(|_| Instant::now())
    }

    /// Close a phase opened at `t0` into the picked histogram.
    fn lap(&self, pick: fn(&MasterObsInner) -> &Histogram, t0: Option<Instant>) {
        if let (Some(o), Some(t0)) = (self.0.as_deref(), t0) {
            pick(o).observe(t0.elapsed().as_secs_f64());
        }
    }

    fn round_done(&self) {
        if let Some(o) = self.0.as_deref() {
            o.rounds.inc();
        }
    }

    /// One eviction: counter plus trace event (round = detection round,
    /// which may precede the boundary the eviction completes at).
    fn eviction(&self, round: u64, epoch: u64, wid: usize) {
        if let Some(o) = self.0.as_deref() {
            o.evictions.inc();
            o.tracer.emit(TraceEvent {
                kind: TraceKind::Eviction,
                run_id: o.run_id,
                round,
                epoch,
                worker: wid as u32,
                value: 0,
            });
        }
    }

    fn admission(&self, round: u64, epoch: u64, wid: usize) {
        if let Some(o) = self.0.as_deref() {
            o.admissions.inc();
            o.tracer.emit(TraceEvent {
                kind: TraceKind::Admission,
                run_id: o.run_id,
                round,
                epoch,
                worker: wid as u32,
                value: 0,
            });
        }
    }

    /// A boundary tick completed: gauges plus the EpochTick event, whose
    /// `value` is the member count after the tick.
    fn fleet_tick(&self, round: u64, epoch: u64, members: u64) {
        if let Some(o) = self.0.as_deref() {
            o.fleet_epoch.set(epoch as f64);
            o.fleet_members.set(members as f64);
            o.tracer.emit(TraceEvent {
                kind: TraceKind::EpochTick,
                run_id: o.run_id,
                round,
                epoch,
                worker: NO_WORKER,
                value: members,
            });
        }
    }

    fn holding(&self, entered: bool, round: u64, epoch: u64) {
        if let Some(o) = self.0.as_deref() {
            o.tracer.emit(TraceEvent {
                kind: if entered { TraceKind::HoldingEnter } else { TraceKind::HoldingLeave },
                run_id: o.run_id,
                round,
                epoch,
                worker: NO_WORKER,
                value: 0,
            });
        }
    }

    /// A committed scheme switch: gauge plus event, both carrying the NEW
    /// epoch (matching the wire: sync_scheme frames are stamped with it).
    fn scheme_switch(&self, round: u64, epoch: u16) {
        if let Some(o) = self.0.as_deref() {
            o.scheme_epoch.set(epoch as f64);
            o.tracer.emit(TraceEvent {
                kind: TraceKind::SchemeSwitch,
                run_id: o.run_id,
                round,
                epoch: epoch as u64,
                worker: NO_WORKER,
                value: 0,
            });
        }
    }

    /// Sample the controller's open-window accumulators (read after
    /// `observe_round`, before the boundary reset in `end_of_round`).
    fn adaptive_window(&self, bits_per_component: f64, residual_energy: f64) {
        if let Some(o) = self.0.as_deref() {
            o.realized_bits.set(bits_per_component);
            o.residual_energy.set(residual_energy);
        }
    }
}

/// Master loop: drives `steps` rounds over the transport.
pub struct MasterLoop<T: MasterTransport> {
    spec: MasterSpec,
    transport: T,
    obs: MasterObs,
}

impl<T: MasterTransport> MasterLoop<T> {
    pub fn new(spec: MasterSpec, transport: T) -> Self {
        Self { spec, transport, obs: MasterObs::off() }
    }

    /// Attach an observability handle (builder style): metrics and trace
    /// events flow through `obs` for this run. The default is
    /// [`MasterObs::off`], the structural bypass.
    pub fn with_observer(mut self, obs: MasterObs) -> Self {
        self.obs = obs;
        self
    }

    /// Model-backed run: PJRT evaluation on held-out batches.
    pub fn run(self, runtime: &Runtime) -> Result<MasterReport> {
        let MasterLoop { spec, transport, obs } = self;
        let model = ModelExec::load(runtime, &spec.model).context("master: load model")?;
        let d = model.entry.d;
        let w = runtime.manifest.load_init(&model.entry)?;
        let test = TestStream::for_model(&model.entry, &spec);
        let mut eval = |w: &[f32], batches: usize, salt: u64| -> Result<(f64, f64)> {
            evaluate(&model, w, &test, batches, salt)
        };
        run_rounds(&spec, transport, w, Some(&mut eval), obs)
    }

    /// Headless run at dimension d: no model, no evaluation (test metrics
    /// are NaN/0); parameters start at zero. The round engine — decode,
    /// per-worker chains, aggregation, broadcast, rate accounting — is the
    /// exact same code as [`Self::run`].
    pub fn run_headless(self, d: usize) -> Result<MasterReport> {
        self.run_headless_from(vec![0.0f32; d])
    }

    /// [`Self::run_headless`] starting from an explicit parameter vector —
    /// what the epoch-switch identity test uses to restart a run from the
    /// absolute `w` a scheme-epoch sync shipped.
    pub fn run_headless_from(self, w: Vec<f32>) -> Result<MasterReport> {
        let MasterLoop { spec, transport, obs } = self;
        run_rounds(&spec, transport, w, None, obs)
    }
}

/// Per-worker frame queues between the transport and the round engine.
struct Inbox {
    /// frames received but not yet folded into an aggregate (FIFO/worker)
    pending: Vec<VecDeque<Frame>>,
    /// total frames received per worker == that worker's round progress
    delivered: Vec<u64>,
    /// this engine's shard id — every arriving frame must carry it (0 on
    /// unsharded fabrics, where every constructor stamps 0)
    shard: u16,
    /// this engine's hosted-run id — 0 everywhere except the multi-tenant
    /// master, whose demux already validates; this is the engine-level
    /// backstop of the same contract (DESIGN.md §11)
    run: u16,
}

impl Inbox {
    fn new(n: usize, shard: u16, run: u16) -> Self {
        Self {
            pending: (0..n).map(|_| VecDeque::new()).collect(),
            delivered: vec![0; n],
            shard,
            run,
        }
    }

    fn push(&mut self, wid: usize, frame: Frame) -> Result<()> {
        anyhow::ensure!(wid < self.pending.len(), "bad worker id {wid}");
        // crossed shard wiring must fail loudly, not decode wrong blocks
        // into wrong chains: same-shaped sub-containers would parse
        anyhow::ensure!(
            frame.shard == self.shard,
            "worker {wid} sent a frame for shard {} to shard {}",
            frame.shard,
            self.shard
        );
        anyhow::ensure!(
            frame.run_id == self.run,
            "worker {wid} sent a frame for run {} to run {}",
            frame.run_id,
            self.run
        );
        self.delivered[wid] += 1;
        self.pending[wid].push_back(frame);
        Ok(())
    }

    /// Pull everything the transport has queued right now.
    fn drain<T: MasterTransport>(&mut self, transport: &mut T) -> Result<()> {
        while let Some((wid, frame)) = transport.try_recv_any()? {
            self.push(wid, frame)?;
        }
        Ok(())
    }

    /// Block for one more frame.
    fn pump<T: MasterTransport>(&mut self, transport: &mut T) -> Result<()> {
        let (wid, frame) = transport.recv_any()?;
        self.push(wid, frame)
    }
}

/// Elastic pump: block for one more frame, bounded by the liveness grace.
/// A full grace window with no traffic at all marks every expired,
/// stalled-on slot as *wedged* — masked out of the expected set with its
/// eviction staged for the next boundary — so the caller's wait condition
/// re-evaluates against the shrunk fleet instead of hanging forever. This
/// is the self-healing counterpart of the fixed-fleet engine's hung-up
/// bail: silence becomes a staged eviction, never a mid-round mutation.
///
/// `require_empty` restricts "stalled-on" to expected slots with no queued
/// frame (the round-wait case — a slot whose frame already arrived is
/// merely waiting its turn, not wedged); the teardown drain passes false
/// because its stall condition is a frame-count shortfall, queued or not.
fn pump_or_expire<T: MasterTransport>(
    inbox: &mut Inbox,
    transport: &mut T,
    fleet: &mut ElasticFleet,
    comm: &mut CommStats,
    grace: Duration,
    require_empty: bool,
    dry_graces: &mut u32,
    t: u64,
    obs: &MasterObs,
) -> Result<()> {
    if let Some((wid, frame)) = transport.recv_any_timeout(grace)? {
        *dry_graces = 0;
        return inbox.push(wid, frame);
    }
    let mut evicted_any = false;
    for wid in transport.expired_peers(grace) {
        if fleet.expected[wid] && (!require_empty || inbox.pending[wid].is_empty()) {
            fleet.mark_wedged(wid);
            comm.record_timeout_eviction();
            obs.eviction(t, fleet.membership.epoch(), wid);
            evicted_any = true;
        }
    }
    if evicted_any {
        *dry_graces = 0;
        return Ok(());
    }
    // nothing arrived and nothing evictable: tolerate a few windows (a
    // reconnect handshake refreshes a peer's liveness clock without
    // producing frames), then fail loudly instead of spinning forever
    *dry_graces += 1;
    anyhow::ensure!(
        *dry_graces < 16,
        "elastic engine stalled: no frames and no evictable peer for {dry_graces} \
         consecutive grace windows of {grace:?}"
    );
    Ok(())
}

/// Top-of-round service for wedged slots: they sit outside the lockstep,
/// but their connection may still deliver frames (a wedge is silence, not
/// death — and an evicted worker keeps answering broadcasts). Control
/// frames feed the membership machine (this is how an evicted-then-
/// recovered worker's Join is heard); Updates are discarded unfolded: the
/// wedged worker's chain advanced while the master's copy did not, so
/// folding it would corrupt the aggregate — the chain is condemned and
/// only a boundary re-admission rebuilds it. A slot that produced frames
/// again *after* its eviction completed is revived (the mask clears) so
/// the next boundary may re-admit it fresh.
fn drain_wedged(inbox: &mut Inbox, fleet: &mut ElasticFleet, comm: &mut CommStats) {
    for wid in 0..inbox.pending.len() {
        if !fleet.is_wedged(wid) {
            continue;
        }
        let mut saw = false;
        while let Some(frame) = inbox.pending[wid].pop_front() {
            saw = true;
            match frame.kind {
                FrameKind::Update => comm.record_unconsumed(1),
                _ => {
                    fleet.observe(wid, &frame);
                    comm.record_skip();
                }
            }
        }
        if saw && !fleet.membership.is_member(wid) {
            fleet.revive(wid);
        }
    }
}

fn run_rounds<T: MasterTransport>(
    spec: &MasterSpec,
    transport: T,
    w: Vec<f32>,
    eval: Option<&mut EvalFn<'_>>,
    obs: MasterObs,
) -> Result<MasterReport> {
    if let Some(plan) = spec.adaptive {
        anyhow::ensure!(
            spec.membership.is_none(),
            "[adaptive] does not compose with [membership]: a fleet boundary and a scheme \
             epoch would race on chain rebuilds"
        );
        return run_engine_adaptive(spec, plan, transport, w, eval, obs);
    }
    if let Some(plan) = spec.membership.clone() {
        return run_engine_elastic(spec, &plan, transport, w, eval, obs);
    }
    let d = w.len();
    let n = transport.n_workers();
    let mut chains: Vec<Box<dyn MasterScheme>> = Vec::with_capacity(n);
    for _ in 0..n {
        chains.push(spec.scheme.master(d)?);
    }
    run_engine(spec, 0, chains, transport, w, eval, obs)
}

/// The reusable fixed-fleet round engine, steppable: decode chains +
/// aggregation + broadcast + LR updates over an injected set of per-worker
/// chains, advanced one round per [`Self::step`]. [`run_engine`] (the
/// single-run masters and the block-sharded master's per-shard engines)
/// drives it to completion in a tight loop; the multi-tenant driver
/// ([`super::multirun`]) sweeps `step()` across R hosted engines on one
/// thread, each over its own [`crate::comm::run::RunPort`] (DESIGN.md §11).
/// Broadcast frames are stamped with `shard` and `run_id` so the worker
/// side can validate routing.
pub(crate) struct RoundEngine<T: MasterTransport> {
    spec: MasterSpec,
    shard: u16,
    run_id: u16,
    chains: Vec<Box<dyn MasterScheme>>,
    transport: T,
    w: Vec<f32>,
    inbox: Inbox,
    comm: CommStats,
    train_loss: LossMeter,
    points: Vec<RunPoint>,
    wall: Timer,
    /// next round to fold: `step()` advances this; `steps` rounds total
    t: u64,
    agg: Vec<f32>,
    /// the broadcast staging buffer ping-pongs through the transport: we
    /// take the bytes back after each broadcast, so warm rounds stage the
    /// dense r̃ with zero heap allocation (ROADMAP "broadcast path reuse")
    bcast_buf: Vec<u8>,
    /// per-worker r̃ buffers for the parallel FullSync decode
    rtilde_w: Vec<Vec<f32>>,
    /// bounded-staleness pools, reused across rounds: per-worker FIFO
    /// batches plus per-frame r̃ scratch and block-bits snapshots for the
    /// parallel batch decode (buffers grow to the high-water frame count
    /// and then stop allocating)
    batches: Vec<Vec<Frame>>,
    stale_scratch: Vec<Vec<Vec<f32>>>,
    stale_snaps: Vec<Vec<Vec<(u64, usize)>>>,
    obs: MasterObs,
}

impl<T: MasterTransport> RoundEngine<T> {
    pub(crate) fn new(
        spec: MasterSpec,
        shard: u16,
        run_id: u16,
        chains: Vec<Box<dyn MasterScheme>>,
        transport: T,
        w: Vec<f32>,
        obs: MasterObs,
    ) -> Result<Self> {
        let d = w.len();
        let n = transport.n_workers();
        anyhow::ensure!(chains.len() == n, "need one chain per worker");
        for chain in &chains {
            anyhow::ensure!(chain.dim() == d, "chain dimension mismatch");
        }
        let full_sync = spec.aggregation == AggMode::FullSync;
        Ok(Self {
            inbox: Inbox::new(n, shard, run_id),
            comm: CommStats::new(d),
            train_loss: LossMeter::new(),
            points: Vec::new(),
            wall: Timer::start(),
            t: 0,
            agg: vec![0.0f32; d],
            bcast_buf: Vec::new(),
            rtilde_w: if full_sync { (0..n).map(|_| vec![0.0f32; d]).collect() } else { Vec::new() },
            batches: if full_sync { Vec::new() } else { (0..n).map(|_| Vec::new()).collect() },
            stale_scratch: if full_sync { Vec::new() } else { (0..n).map(|_| Vec::new()).collect() },
            stale_snaps: if full_sync { Vec::new() } else { (0..n).map(|_| Vec::new()).collect() },
            spec,
            shard,
            run_id,
            chains,
            transport,
            w,
            obs,
        })
    }

    /// All `steps` rounds folded — nothing left but [`Self::finish`].
    pub(crate) fn done(&self) -> bool {
        self.t >= self.spec.steps
    }

    /// Rounds folded so far (the multi-run driver's fairness probe).
    pub(crate) fn rounds_done(&self) -> u64 {
        self.t
    }

    /// Fold one round and broadcast the result.
    pub(crate) fn step(&mut self, mut eval: Option<&mut EvalFn<'_>>) -> Result<()> {
        let t = self.t;
        let d = self.w.len();
        let n = self.transport.n_workers();
        self.agg.iter_mut().for_each(|x| *x = 0.0);
        let t_wait = self.obs.now();

        match self.spec.aggregation {
            AggMode::FullSync => {
                // one frame per worker, then fold in worker-id order — the
                // ordering that makes TCP and channel runs bit-identical
                while self.inbox.pending.iter().any(|q| q.is_empty()) {
                    self.inbox.pump(&mut self.transport)?;
                }
                self.obs.lap(|o| &o.wait_secs, t_wait);
                let mut round_frames = Vec::with_capacity(n);
                for wid in 0..n {
                    let frame = self.inbox.pending[wid].pop_front().unwrap();
                    anyhow::ensure!(
                        frame.round == t,
                        "round skew: worker {wid} sent {} during round {t}",
                        frame.round
                    );
                    round_frames.push(frame);
                }
                let contributors =
                    round_frames.iter().filter(|f| f.kind == FrameKind::Update).count();
                let scale = if contributors > 0 { 1.0 / contributors as f32 } else { 0.0 };
                // decode every worker's chain in parallel (chains are
                // independent per worker); accounting and aggregation below
                // stay in worker-id order, so the folded f32 bits are
                // identical to the sequential path for any thread count
                let t_decode = self.obs.now();
                decode_round_parallel(&mut self.chains, &mut self.rtilde_w, &mut round_frames, t, d)?;
                self.obs.lap(|o| &o.decode_secs, t_decode);
                let t_fold = self.obs.now();
                for (wid, frame) in round_frames.iter().enumerate() {
                    account_frame(
                        frame,
                        wid,
                        &*self.chains[wid],
                        &mut self.comm,
                        &mut self.train_loss,
                    )?;
                    if frame.kind == FrameKind::Update {
                        let rt = &self.rtilde_w[wid];
                        for i in 0..d {
                            self.agg[i] += scale * rt[i];
                        }
                    }
                }
                self.obs.lap(|o| &o.fold_secs, t_fold);
            }
            AggMode::BoundedStaleness { max_staleness, quorum } => {
                self.inbox.drain(&mut self.transport)?;
                // staleness bound: worker w's latest delivered round is
                // delivered[w]-1; it may not trail round t by more than S
                for wid in 0..n {
                    while self.inbox.delivered[wid] + max_staleness < t + 1 {
                        self.inbox.pump(&mut self.transport)?;
                    }
                }
                // quorum: enough workers must have at least one frame queued
                let quorum = quorum.clamp(1, n);
                while self.inbox.pending.iter().filter(|q| !q.is_empty()).count() < quorum {
                    self.inbox.pump(&mut self.transport)?;
                }
                self.obs.lap(|o| &o.wait_secs, t_wait);
                // take EVERY queued frame, each exactly once, per-worker
                // FIFO, then decode the batches in parallel across workers
                // (sequential within a worker: chains advance in the
                // worker's own round order). Accounting and aggregation
                // below replay in worker-id order from per-frame snapshots,
                // so the folded f32 bits and CommStats are identical to the
                // decode-as-you-fold path at any thread count (pinned by
                // tests/hotpath_parallel.rs).
                for wid in 0..n {
                    self.batches[wid].clear();
                    while let Some(frame) = self.inbox.pending[wid].pop_front() {
                        anyhow::ensure!(
                            frame.worker as usize == wid,
                            "worker id mismatch: frame from {} on queue {wid}",
                            frame.worker
                        );
                        self.batches[wid].push(frame);
                    }
                }
                let t_decode = self.obs.now();
                decode_batches_parallel(
                    &mut self.chains,
                    &mut self.batches,
                    &mut self.stale_scratch,
                    &mut self.stale_snaps,
                    t,
                    d,
                )?;
                self.obs.lap(|o| &o.decode_secs, t_decode);
                let t_fold = self.obs.now();
                let mut contributions = 0u32;
                for wid in 0..n {
                    for (k, frame) in self.batches[wid].iter().enumerate() {
                        if frame.kind == FrameKind::Update {
                            self.comm.record_staleness(t.saturating_sub(frame.round));
                        }
                        account_decoded(
                            frame,
                            wid,
                            &*self.chains[wid],
                            &self.stale_snaps[wid][k],
                            &mut self.comm,
                            &mut self.train_loss,
                        )?;
                        if frame.kind == FrameKind::Update {
                            contributions += 1;
                            let rt = &self.stale_scratch[wid][k];
                            for i in 0..d {
                                self.agg[i] += rt[i];
                            }
                        }
                    }
                }
                if contributions > 0 {
                    let scale = 1.0 / contributions as f32;
                    for a in self.agg.iter_mut() {
                        *a *= scale;
                    }
                }
                self.obs.lap(|o| &o.fold_secs, t_fold);
            }
        }

        // broadcast the averaged r̃; workers (and we) apply w -= η·agg
        let t_bcast = self.obs.now();
        let mut frame = Frame::broadcast_from(t, &self.agg, std::mem::take(&mut self.bcast_buf));
        frame.shard = self.shard;
        frame.run_id = self.run_id;
        self.transport.broadcast(&frame)?;
        self.obs.lap(|o| &o.broadcast_secs, t_bcast);
        self.bcast_buf = frame.bytes;
        let lr = self.spec.schedule.lr_at(t);
        for i in 0..d {
            self.w[i] -= lr * self.agg[i];
        }

        if (t + 1) % self.spec.eval_every == 0 || t + 1 == self.spec.steps {
            let (test_loss, test_acc) = match eval.as_mut() {
                Some(f) => f(&self.w, self.spec.eval_batches, t)?,
                None => (f64::NAN, 0.0),
            };
            self.points.push(RunPoint {
                step: t + 1,
                epoch_equiv: ((t + 1) as f64 * self.spec.samples_per_round as f64)
                    / self.spec.train_len.max(1) as f64,
                train_loss: self.train_loss.smoothed(),
                test_loss,
                test_acc,
                bits_per_component: self.comm.bits_per_component(),
                e_mse: 0.0, // filled from worker traces by launch glue
                wall_secs: self.wall.elapsed_secs(),
            });
        }
        self.obs.round_done();
        self.t += 1;
        Ok(())
    }

    /// Teardown after the last round: drain in-flight frames and run the
    /// final evaluation.
    pub(crate) fn finish(mut self, mut eval: Option<&mut EvalFn<'_>>) -> Result<MasterReport> {
        debug_assert!(self.done());
        // bounded-staleness runs can end with late updates still in flight;
        // drain them (every worker sends exactly `steps` frames) so worker
        // threads never see a torn-down fabric mid-send, and account the
        // updates the horizon cut off
        if self.spec.aggregation != AggMode::FullSync {
            for wid in 0..self.inbox.pending.len() {
                while self.inbox.delivered[wid] < self.spec.steps {
                    self.inbox.pump(&mut self.transport)?;
                }
            }
            let unconsumed = self
                .inbox
                .pending
                .iter()
                .flat_map(|q| q.iter())
                .filter(|f| f.kind == FrameKind::Update)
                .count();
            self.comm.record_unconsumed(unconsumed as u64);
        }

        let (final_test_loss, final_test_acc) = match eval.as_mut() {
            Some(f) => f(&self.w, (self.spec.eval_batches * 4).max(8), self.spec.steps)?,
            None => (f64::NAN, 0.0),
        };
        Ok(MasterReport {
            points: self.points,
            comm: self.comm,
            final_test_acc,
            final_test_loss,
            final_w_norm: crate::tensor::norm2(&self.w),
            final_w: self.w,
        })
    }
}

/// Drive a [`RoundEngine`] to completion — the single-run entry the
/// whole-vector master and the block-sharded master
/// ([`super::shard::ShardedMasterLoop`]) call, unchanged in behavior from
/// the pre-steppable engine (pure code motion; bit-identity pinned by the
/// fabric/shard identity suites).
pub(crate) fn run_engine<T: MasterTransport>(
    spec: &MasterSpec,
    shard: u16,
    chains: Vec<Box<dyn MasterScheme>>,
    transport: T,
    w: Vec<f32>,
    mut eval: Option<&mut EvalFn<'_>>,
    obs: MasterObs,
) -> Result<MasterReport> {
    let mut engine = RoundEngine::new(spec.clone(), shard, 0, chains, transport, w, obs)?;
    while !engine.done() {
        engine.step(eval.as_deref_mut())?;
    }
    engine.finish(eval)
}

/// The elastic round engine (`[membership]` configured): the fixed-fleet
/// engine promoted to the epoch-phased coordinator state machine of
/// [`crate::coordinator::membership`] (DESIGN.md §7).
///
/// Protocol invariants (shared by every fabric backend — the admission
/// path is this engine, not the transport):
///
/// * **Lockstep holds.** Every *expected* slot (a connected worker the
///   previous broadcast reached) sends exactly one frame per round:
///   members send Update, a member announcing departure sends Leave (its
///   contribution for that round is forfeited), connected non-members
///   send Join (seeking next-epoch admission) or Skip. Join/Leave only
///   *stage* changes; the member set mutates exclusively at boundaries.
/// * **Boundaries.** After folding round `t` with `(t+1) % admit_at == 0`
///   the machine ticks: leavers evicted, parked joiners admitted (fresh
///   master chain via `scheme.master(d)` — the chain-reset contract), and
///   the broadcast becomes a [`Frame::sync_w`] carrying the new member
///   bitmap plus the **absolute** post-round parameters, so admitted
///   workers re-enter bit-exactly in sync.
/// * **Expected = last broadcast's roster.** A worker only sends after
///   receiving a broadcast, and [`MasterTransport::broadcast_roster`]
///   reports exactly who a broadcast was staged to — so a connection that
///   completes mid-round is picked up at the next broadcast and can never
///   deadlock the wait loop.
/// * **Bounded staleness** re-times its bounds by each slot's first
///   expected round; `admit_at > max_staleness` (validated here) plus
///   per-connection FIFO guarantee every pre-eviction Update folds into
///   the old chain before any boundary can rebuild it.
///
/// * **Liveness deadlines (DESIGN.md §10).** Every wait loop is bounded by
///   the plan's `dead_grace`: a full grace window with no traffic marks the
///   expired stalled-on slots wedged ([`pump_or_expire`]) — masked out of
///   the expected set, eviction staged — and a boundary sweep catches
///   crashed members no loop ever stalls on. The member set still mutates
///   only at `tick()`; a wedge never rewrites a round in flight. Fault-free
///   runs never hit a deadline, so the fixed-fleet identity pin is intact.
/// * **Holding.** If eviction drops the fleet below `min_workers`, the
///   machine parks in `Phase::Holding`: remaining members demote to
///   pending, the bitmap empties, and rounds keep broadcasting (folding
///   nothing, `w` frozen) until a boundary finds quorum again.
///
/// With `min_workers == max_workers == fleet` and every worker seeking
/// every epoch, no Join/Leave frames exist and no rekeys fire: the run is
/// bit-identical (final_w bits, CommStats, StepStats) to the fixed-fleet
/// engine (pinned by `tests/membership_e2e.rs`).
pub(crate) fn run_engine_elastic<T: MasterTransport>(
    spec: &MasterSpec,
    plan: &MembershipPlan,
    mut transport: T,
    mut w: Vec<f32>,
    mut eval: Option<&mut EvalFn<'_>>,
    obs: MasterObs,
) -> Result<MasterReport> {
    let d = w.len();
    let n = transport.n_workers();
    if let AggMode::BoundedStaleness { max_staleness, .. } = spec.aggregation {
        anyhow::ensure!(
            plan.spec.admit_at > max_staleness,
            "[membership] admit_at ({}) must exceed max_staleness ({max_staleness}): in-flight \
             stale updates must drain before a boundary may rebuild a chain",
            plan.spec.admit_at
        );
    }
    let mut chains: Vec<Box<dyn MasterScheme>> = Vec::with_capacity(n);
    for _ in 0..n {
        chains.push(spec.scheme.master(d)?);
    }
    let mut fleet = ElasticFleet::new(plan, n)?;
    let mut inbox = Inbox::new(n, 0, 0);
    let mut comm = CommStats::new(d);
    let mut train_loss = LossMeter::new();
    let mut points = Vec::new();
    let wall = Timer::start();
    let grace = plan.dead_grace;
    let mut dry_graces = 0u32;

    let mut agg = vec![0.0f32; d];
    let mut bcast_buf: Vec<u8> = Vec::new();
    let mut round_frames: Vec<Frame> = Vec::with_capacity(n);
    let mut rtilde_w: Vec<Vec<f32>> = match spec.aggregation {
        AggMode::FullSync => (0..n).map(|_| vec![0.0f32; d]).collect(),
        _ => Vec::new(),
    };
    let mut batches: Vec<Vec<Frame>> = Vec::new();
    let mut stale_scratch: Vec<Vec<Vec<f32>>> = Vec::new();
    let mut stale_snaps: Vec<Vec<Vec<(u64, usize)>>> = Vec::new();
    if spec.aggregation != AggMode::FullSync {
        batches = (0..n).map(|_| Vec::new()).collect();
        stale_scratch = (0..n).map(|_| Vec::new()).collect();
        stale_snaps = (0..n).map(|_| Vec::new()).collect();
    }

    // pre-round-0 beacon: hands every connected worker the member bitmap
    // and the initial parameters; its recipient roster seeds the expected
    // set for round 0
    let frame =
        Frame::sync_w(SYNC_ROUND, &w, fleet.membership.bitmap(), std::mem::take(&mut bcast_buf));
    let roster = transport.broadcast_roster(&frame)?;
    bcast_buf = frame.bytes;
    fleet.set_expected(roster, 0);

    for t in 0..spec.steps {
        agg.iter_mut().for_each(|x| *x = 0.0);
        drain_wedged(&mut inbox, &mut fleet, &mut comm);
        let t_wait = obs.now();

        match spec.aggregation {
            AggMode::FullSync => {
                // one frame per EXPECTED slot, then fold in worker-id order
                loop {
                    // a slot revived mid-epoch may have parked control
                    // frames from rounds it sat out; shed them (observing
                    // Join/Leave) so the lockstep round check below only
                    // ever sees the slot's current-round frame
                    for wid in 0..n {
                        if !fleet.expected[wid] {
                            continue;
                        }
                        while matches!(
                            inbox.pending[wid].front(),
                            Some(f) if f.round < t && f.kind != FrameKind::Update
                        ) {
                            let stale = inbox.pending[wid].pop_front().unwrap();
                            fleet.observe(wid, &stale);
                            comm.record_skip();
                        }
                    }
                    if !(0..n).any(|wid| fleet.expected[wid] && inbox.pending[wid].is_empty()) {
                        break;
                    }
                    pump_or_expire(
                        &mut inbox,
                        &mut transport,
                        &mut fleet,
                        &mut comm,
                        grace,
                        true,
                        &mut dry_graces,
                        t,
                        &obs,
                    )?;
                }
                obs.lap(|o| &o.wait_secs, t_wait);
                round_frames.clear();
                for wid in 0..n {
                    if fleet.expected[wid] {
                        let frame = inbox.pending[wid].pop_front().unwrap();
                        anyhow::ensure!(
                            frame.round == t,
                            "round skew: worker {wid} sent {} during round {t}",
                            frame.round
                        );
                        anyhow::ensure!(
                            frame.worker as usize == wid,
                            "worker id mismatch: frame from {} on queue {wid}",
                            frame.worker
                        );
                        fleet.observe(wid, &frame);
                        round_frames.push(frame);
                    } else {
                        // placeholder keeps the decode slot zip dense; it
                        // is never accounted (the slot owes us nothing)
                        round_frames.push(Frame::skip(wid as u32, t));
                    }
                }
                let contributors = (0..n)
                    .filter(|&wid| {
                        fleet.expected[wid] && round_frames[wid].kind == FrameKind::Update
                    })
                    .count();
                let scale = if contributors > 0 { 1.0 / contributors as f32 } else { 0.0 };
                let t_decode = obs.now();
                decode_round_parallel(&mut chains, &mut rtilde_w, &mut round_frames, t, d)?;
                obs.lap(|o| &o.decode_secs, t_decode);
                let t_fold = obs.now();
                for wid in 0..n {
                    if !fleet.expected[wid] {
                        continue;
                    }
                    let frame = &round_frames[wid];
                    match frame.kind {
                        FrameKind::Update => {
                            anyhow::ensure!(
                                fleet.membership.is_member(wid),
                                "round {t}: update from non-member worker {wid}"
                            );
                            account_frame(frame, wid, &*chains[wid], &mut comm, &mut train_loss)?;
                            let rt = &rtilde_w[wid];
                            for i in 0..d {
                                agg[i] += scale * rt[i];
                            }
                        }
                        // control frames and sit-outs: staged above via
                        // observe(); all count as a skipped round
                        FrameKind::Skip | FrameKind::Join | FrameKind::Leave => comm.record_skip(),
                        other => anyhow::bail!("unexpected {other:?} frame from worker {wid}"),
                    }
                }
                obs.lap(|o| &o.fold_secs, t_fold);
            }
            AggMode::BoundedStaleness { max_staleness, quorum } => {
                inbox.drain(&mut transport)?;
                // staleness bound, re-timed by each slot's first expected
                // round: a worker first expected at round s has sent
                // delivered frames covering rounds s..s+delivered
                for wid in 0..n {
                    while fleet.expected[wid]
                        && fleet.start_round[wid] + inbox.delivered[wid] + max_staleness < t + 1
                    {
                        pump_or_expire(
                            &mut inbox,
                            &mut transport,
                            &mut fleet,
                            &mut comm,
                            grace,
                            true,
                            &mut dry_graces,
                            t,
                            &obs,
                        )?;
                    }
                }
                // quorum re-clamps every pass: a wedge mid-wait shrinks
                // the expected set, and demanding the stale count would
                // deadlock on workers that no longer exist
                loop {
                    let expected_now = fleet.expected_count();
                    if expected_now == 0 {
                        break;
                    }
                    let need = quorum.clamp(1, expected_now);
                    let have = (0..n)
                        .filter(|&wid| fleet.expected[wid] && !inbox.pending[wid].is_empty())
                        .count();
                    if have >= need {
                        break;
                    }
                    pump_or_expire(
                        &mut inbox,
                        &mut transport,
                        &mut fleet,
                        &mut comm,
                        grace,
                        true,
                        &mut dry_graces,
                        t,
                        &obs,
                    )?;
                }
                obs.lap(|o| &o.wait_secs, t_wait);
                for wid in 0..n {
                    batches[wid].clear();
                    if fleet.is_wedged(wid) {
                        // a wedged slot's chain is condemned: anything its
                        // connection delivers mid-round parks until the
                        // next round's drain_wedged, never the fold
                        continue;
                    }
                    while let Some(frame) = inbox.pending[wid].pop_front() {
                        anyhow::ensure!(
                            frame.worker as usize == wid,
                            "worker id mismatch: frame from {} on queue {wid}",
                            frame.worker
                        );
                        fleet.observe(wid, &frame);
                        batches[wid].push(frame);
                    }
                }
                let t_decode = obs.now();
                decode_batches_parallel(
                    &mut chains,
                    &mut batches,
                    &mut stale_scratch,
                    &mut stale_snaps,
                    t,
                    d,
                )?;
                obs.lap(|o| &o.decode_secs, t_decode);
                let t_fold = obs.now();
                let mut contributions = 0u32;
                for wid in 0..n {
                    for (k, frame) in batches[wid].iter().enumerate() {
                        match frame.kind {
                            FrameKind::Join | FrameKind::Leave => comm.record_skip(),
                            _ => {
                                if frame.kind == FrameKind::Update {
                                    comm.record_staleness(t.saturating_sub(frame.round));
                                }
                                account_decoded(
                                    frame,
                                    wid,
                                    &*chains[wid],
                                    &stale_snaps[wid][k],
                                    &mut comm,
                                    &mut train_loss,
                                )?;
                                if frame.kind == FrameKind::Update {
                                    contributions += 1;
                                    let rt = &stale_scratch[wid][k];
                                    for i in 0..d {
                                        agg[i] += rt[i];
                                    }
                                }
                            }
                        }
                    }
                }
                if contributions > 0 {
                    let scale = 1.0 / contributions as f32;
                    for a in agg.iter_mut() {
                        *a *= scale;
                    }
                }
                obs.lap(|o| &o.fold_secs, t_fold);
            }
        }

        // the master applies its own delta BEFORE broadcasting, so a
        // boundary sync ships the post-round-t parameters (identical f32
        // bits to every member applying the delta itself)
        let lr = spec.schedule.lr_at(t);
        for i in 0..d {
            w[i] -= lr * agg[i];
        }
        let boundary = (t + 1) % fleet.admit_at == 0;
        let frame = if boundary {
            // liveness sweep: a crashed member's connection is gone, so it
            // is never expected and no wait loop ever stalls on it — stage
            // its eviction here before the machine ticks. Fault-free runs
            // keep every member expected, so this is a no-op and the
            // boundary stays bit-identical.
            for wid in transport.expired_peers(grace) {
                if fleet.membership.is_member(wid)
                    && !fleet.expected[wid]
                    && !fleet.is_wedged(wid)
                {
                    fleet.mark_wedged(wid);
                    comm.record_timeout_eviction();
                    obs.eviction(t, fleet.membership.epoch(), wid);
                }
            }
            let phase_before = fleet.membership.phase();
            let diff = fleet.membership.tick();
            let epoch_now = fleet.membership.epoch();
            // EpochTick first (value = member count after the tick), then
            // one Admission per admitted slot, then any Holding transition
            // — the order the chaos-wedge e2e timeline asserts
            obs.fleet_tick(t, epoch_now, u64::from(fleet.membership.bitmap().count_ones()));
            for &wid in &diff.admitted {
                // chain-reset contract: admission rebuilds the worker's
                // decode chain from scratch (evicted chains are left
                // behind and replaced here if the worker ever returns)
                chains[wid] = spec.scheme.master(d)?;
                obs.admission(t, epoch_now, wid);
            }
            let phase_after = fleet.membership.phase();
            if phase_after == Phase::Holding && phase_before != Phase::Holding {
                obs.holding(true, t, epoch_now);
            } else if phase_before == Phase::Holding && phase_after != Phase::Holding {
                obs.holding(false, t, epoch_now);
            }
            Frame::sync_w(t, &w, fleet.membership.bitmap(), std::mem::take(&mut bcast_buf))
        } else {
            // plain delta broadcast, bitmap riding in payload_bits so a
            // freshly connected worker learns the current member set
            let mut f = Frame::broadcast_from(t, &agg, std::mem::take(&mut bcast_buf));
            f.payload_bits = fleet.membership.bitmap();
            f
        };
        let t_bcast = obs.now();
        let roster = transport.broadcast_roster(&frame)?;
        obs.lap(|o| &o.broadcast_secs, t_bcast);
        bcast_buf = frame.bytes;
        fleet.set_expected(roster, t + 1);

        if (t + 1) % spec.eval_every == 0 || t + 1 == spec.steps {
            let (test_loss, test_acc) = match eval.as_mut() {
                Some(f) => f(&w, spec.eval_batches, t)?,
                None => (f64::NAN, 0.0),
            };
            points.push(RunPoint {
                step: t + 1,
                epoch_equiv: ((t + 1) as f64 * spec.samples_per_round as f64)
                    / spec.train_len.max(1) as f64,
                train_loss: train_loss.smoothed(),
                test_loss,
                test_acc,
                bits_per_component: comm.bits_per_component(),
                e_mse: 0.0,
                wall_secs: wall.elapsed_secs(),
            });
        }
        obs.round_done();
    }

    // bounded-staleness runs can end with late frames still in flight: a
    // slot first expected at round s sends exactly steps - s frames. A
    // worker that wedges during teardown is expired out of the wait (its
    // tail frames are forfeit) rather than hanging the master forever.
    if spec.aggregation != AggMode::FullSync {
        for wid in 0..n {
            while fleet.expected[wid]
                && fleet.start_round[wid] + inbox.delivered[wid] < spec.steps
            {
                pump_or_expire(
                    &mut inbox,
                    &mut transport,
                    &mut fleet,
                    &mut comm,
                    grace,
                    false,
                    &mut dry_graces,
                    spec.steps,
                    &obs,
                )?;
            }
        }
        let unconsumed = inbox
            .pending
            .iter()
            .flat_map(|q| q.iter())
            .filter(|f| f.kind == FrameKind::Update)
            .count();
        comm.record_unconsumed(unconsumed as u64);
    }

    let (final_test_loss, final_test_acc) = match eval.as_mut() {
        Some(f) => f(&w, (spec.eval_batches * 4).max(8), spec.steps)?,
        None => (f64::NAN, 0.0),
    };
    Ok(MasterReport {
        points,
        comm,
        final_test_acc,
        final_test_loss,
        final_w_norm: crate::tensor::norm2(&w),
        final_w: w,
    })
}

/// The adaptive round engine (`[adaptive]` configured): the fixed-fleet
/// engine promoted to the negotiated scheme-epoch state machine of
/// [`crate::scheme::adaptive`] (DESIGN.md §8).
///
/// Protocol invariants (the negotiation is this engine, not the transport):
///
/// * **Epochs are master-declared.** The [`RateController`] decides at
///   window boundaries only; a switch after folding round `t` makes the
///   broadcast a [`Frame::sync_scheme`] carrying the **absolute**
///   post-round parameters plus the next epoch's spec string, stamped with
///   the NEW epoch number. Plain broadcasts carry the delta and the
///   CURRENT epoch.
/// * **Both sides rebuild whole.** On a switch the master rebuilds every
///   worker's decode chain from the new spec; the worker rebuilds its
///   whole pipeline and adopts the broadcast `w` — the same chain-reset
///   contract as elastic admission, applied fleet-wide, which is what
///   makes the epoch-switch identity hold (a switched run continues
///   bit-identically to a fresh run started from the synced `w`).
/// * **Epoch tags close the loop.** Workers stamp every update with the
///   epoch they coded under; the master rejects a mismatched tag instead
///   of decoding bytes with the wrong codec.
/// * **Boundaries are drain barriers.** Under bounded staleness the master
///   pumps until every worker's frames through round `t` have arrived
///   (and folds them) before it may decide — no in-flight update can
///   straddle a chain rebuild. `window > max_staleness` (validated here)
///   keeps the barrier from re-serializing every round.
pub(crate) fn run_engine_adaptive<T: MasterTransport>(
    spec: &MasterSpec,
    plan: AdaptivePlan,
    mut transport: T,
    mut w: Vec<f32>,
    mut eval: Option<&mut EvalFn<'_>>,
    obs: MasterObs,
) -> Result<MasterReport> {
    let d = w.len();
    let n = transport.n_workers();
    if let AggMode::BoundedStaleness { max_staleness, .. } = spec.aggregation {
        anyhow::ensure!(
            plan.window > max_staleness,
            "[adaptive] window ({}) must exceed max_staleness ({max_staleness}): a scheme \
             switch is a drain barrier and must not re-serialize every round",
            plan.window
        );
    }
    let mut ctrl = RateController::new(plan, spec.scheme.clone(), d)?;
    let mut epoch: u16 = 0;
    let mut chains: Vec<Box<dyn MasterScheme>> = Vec::with_capacity(n);
    for _ in 0..n {
        chains.push(spec.scheme.master(d)?);
    }
    let mut inbox = Inbox::new(n, 0, 0);
    let mut comm = CommStats::new(d);
    comm.begin_scheme_epoch(0, &spec.scheme.spec());
    let mut train_loss = LossMeter::new();
    let mut points = Vec::new();
    let wall = Timer::start();

    let mut agg = vec![0.0f32; d];
    let mut bcast_buf: Vec<u8> = Vec::new();
    let mut rtilde_w: Vec<Vec<f32>> = match spec.aggregation {
        AggMode::FullSync => (0..n).map(|_| vec![0.0f32; d]).collect(),
        _ => Vec::new(),
    };
    let mut batches: Vec<Vec<Frame>> = Vec::new();
    let mut stale_scratch: Vec<Vec<Vec<f32>>> = Vec::new();
    let mut stale_snaps: Vec<Vec<Vec<(u64, usize)>>> = Vec::new();
    if spec.aggregation != AggMode::FullSync {
        batches = (0..n).map(|_| Vec::new()).collect();
        stale_scratch = (0..n).map(|_| Vec::new()).collect();
        stale_snaps = (0..n).map(|_| Vec::new()).collect();
    }

    for t in 0..spec.steps {
        agg.iter_mut().for_each(|x| *x = 0.0);
        let boundary = (t + 1) % ctrl.plan().window == 0;
        let t_wait = obs.now();

        match spec.aggregation {
            AggMode::FullSync => {
                while inbox.pending.iter().any(|q| q.is_empty()) {
                    inbox.pump(&mut transport)?;
                }
                obs.lap(|o| &o.wait_secs, t_wait);
                let mut round_frames = Vec::with_capacity(n);
                for wid in 0..n {
                    let frame = inbox.pending[wid].pop_front().unwrap();
                    anyhow::ensure!(
                        frame.round == t,
                        "round skew: worker {wid} sent {} during round {t}",
                        frame.round
                    );
                    if frame.kind == FrameKind::Update {
                        anyhow::ensure!(
                            frame.scheme_epoch == epoch,
                            "scheme-epoch skew: worker {wid} coded round {t} under epoch {} \
                             during epoch {epoch}",
                            frame.scheme_epoch
                        );
                    }
                    round_frames.push(frame);
                }
                let contributors =
                    round_frames.iter().filter(|f| f.kind == FrameKind::Update).count();
                let scale = if contributors > 0 { 1.0 / contributors as f32 } else { 0.0 };
                let t_decode = obs.now();
                decode_round_parallel(&mut chains, &mut rtilde_w, &mut round_frames, t, d)?;
                obs.lap(|o| &o.decode_secs, t_decode);
                let t_fold = obs.now();
                for (wid, frame) in round_frames.iter().enumerate() {
                    account_frame(frame, wid, &*chains[wid], &mut comm, &mut train_loss)?;
                    if frame.kind == FrameKind::Update {
                        ctrl.observe_message(frame.payload_bits);
                        let rt = &rtilde_w[wid];
                        for i in 0..d {
                            agg[i] += scale * rt[i];
                        }
                    }
                }
                obs.lap(|o| &o.fold_secs, t_fold);
            }
            AggMode::BoundedStaleness { max_staleness, quorum } => {
                inbox.drain(&mut transport)?;
                // the boundary drain barrier: every frame through round t
                // must fold before the controller may rebuild chains
                let caught_up =
                    if boundary { t + 1 } else { (t + 1).saturating_sub(max_staleness) };
                for wid in 0..n {
                    while inbox.delivered[wid] < caught_up {
                        inbox.pump(&mut transport)?;
                    }
                }
                let quorum = quorum.clamp(1, n);
                while inbox.pending.iter().filter(|q| !q.is_empty()).count() < quorum {
                    inbox.pump(&mut transport)?;
                }
                obs.lap(|o| &o.wait_secs, t_wait);
                for wid in 0..n {
                    batches[wid].clear();
                    while let Some(frame) = inbox.pending[wid].pop_front() {
                        anyhow::ensure!(
                            frame.worker as usize == wid,
                            "worker id mismatch: frame from {} on queue {wid}",
                            frame.worker
                        );
                        if frame.kind == FrameKind::Update {
                            anyhow::ensure!(
                                frame.scheme_epoch == epoch,
                                "scheme-epoch skew: worker {wid} coded round {} under epoch {} \
                                 during epoch {epoch}",
                                frame.round,
                                frame.scheme_epoch
                            );
                        }
                        batches[wid].push(frame);
                    }
                }
                let t_decode = obs.now();
                decode_batches_parallel(
                    &mut chains,
                    &mut batches,
                    &mut stale_scratch,
                    &mut stale_snaps,
                    t,
                    d,
                )?;
                obs.lap(|o| &o.decode_secs, t_decode);
                let t_fold = obs.now();
                let mut contributions = 0u32;
                for wid in 0..n {
                    for (k, frame) in batches[wid].iter().enumerate() {
                        if frame.kind == FrameKind::Update {
                            comm.record_staleness(t.saturating_sub(frame.round));
                        }
                        account_decoded(
                            frame,
                            wid,
                            &*chains[wid],
                            &stale_snaps[wid][k],
                            &mut comm,
                            &mut train_loss,
                        )?;
                        if frame.kind == FrameKind::Update {
                            ctrl.observe_message(frame.payload_bits);
                            contributions += 1;
                            let rt = &stale_scratch[wid][k];
                            for i in 0..d {
                                agg[i] += rt[i];
                            }
                        }
                    }
                }
                if contributions > 0 {
                    let scale = 1.0 / contributions as f32;
                    for a in agg.iter_mut() {
                        *a *= scale;
                    }
                }
                obs.lap(|o| &o.fold_secs, t_fold);
            }
        }
        ctrl.observe_round(&agg);
        // sample the open window before a boundary's end_of_round resets it
        obs.adaptive_window(ctrl.window_bits_per_component(), ctrl.window_residual_energy());

        // the master applies its own delta BEFORE broadcasting, so a switch
        // ships the post-round-t parameters (identical f32 bits to every
        // worker applying the delta itself)
        let lr = spec.schedule.lr_at(t);
        for i in 0..d {
            w[i] -= lr * agg[i];
        }
        let frame = match ctrl.end_of_round(t)? {
            Some(sw) => {
                // whole-fleet chain-reset contract: every decode chain is
                // rebuilt from the new spec, exactly as a fresh run would
                // build it (the epoch-switch identity leans on this)
                for chain in chains.iter_mut() {
                    *chain = sw.scheme.master(d)?;
                }
                epoch = sw.epoch;
                let spec_str = sw.scheme.spec();
                comm.begin_scheme_epoch(epoch, &spec_str);
                obs.scheme_switch(t, epoch);
                Frame::sync_scheme(t, &w, &spec_str, epoch, std::mem::take(&mut bcast_buf))
            }
            None => Frame::broadcast_from(t, &agg, std::mem::take(&mut bcast_buf))
                .with_scheme_epoch(epoch),
        };
        let t_bcast = obs.now();
        transport.broadcast(&frame)?;
        obs.lap(|o| &o.broadcast_secs, t_bcast);
        bcast_buf = frame.bytes;

        if (t + 1) % spec.eval_every == 0 || t + 1 == spec.steps {
            let (test_loss, test_acc) = match eval.as_mut() {
                Some(f) => f(&w, spec.eval_batches, t)?,
                None => (f64::NAN, 0.0),
            };
            points.push(RunPoint {
                step: t + 1,
                epoch_equiv: ((t + 1) as f64 * spec.samples_per_round as f64)
                    / spec.train_len.max(1) as f64,
                train_loss: train_loss.smoothed(),
                test_loss,
                test_acc,
                bits_per_component: comm.bits_per_component(),
                e_mse: 0.0,
                wall_secs: wall.elapsed_secs(),
            });
        }
        obs.round_done();
    }

    // bounded-staleness teardown: every worker sends exactly `steps`
    // frames; with `steps` a window multiple the final boundary barrier
    // already drained them, but partial trailing windows can leave frames
    if spec.aggregation != AggMode::FullSync {
        for wid in 0..n {
            while inbox.delivered[wid] < spec.steps {
                inbox.pump(&mut transport)?;
            }
        }
        let unconsumed = inbox
            .pending
            .iter()
            .flat_map(|q| q.iter())
            .filter(|f| f.kind == FrameKind::Update)
            .count();
        comm.record_unconsumed(unconsumed as u64);
    }

    let (final_test_loss, final_test_acc) = match eval.as_mut() {
        Some(f) => f(&w, (spec.eval_batches * 4).max(8), spec.steps)?,
        None => (f64::NAN, 0.0),
    };
    Ok(MasterReport {
        points,
        comm,
        final_test_acc,
        final_test_loss,
        final_w_norm: crate::tensor::norm2(&w),
        final_w: w,
    })
}

/// Decode one FullSync round's frames — one independent decode chain per
/// worker — across scoped threads (serial below
/// `util::parallel::PAR_MIN_DIM` or for one worker; outputs are
/// bit-identical either way). Each worker's r̃ lands in its own `rtilde_w`
/// slot; the caller folds those in worker-id order.
/// Decode failures surface in worker-id order with the same context the
/// sequential path attached.
fn decode_round_parallel(
    chains: &mut [Box<dyn MasterScheme>],
    rtilde_w: &mut [Vec<f32>],
    frames: &mut [Frame],
    round: u64,
    d: usize,
) -> Result<()> {
    let n = frames.len();
    let mut results: Vec<Result<()>> = Vec::with_capacity(n);
    results.resize_with(n, || Ok(()));
    {
        type Slot<'a> = (
            &'a mut Box<dyn MasterScheme>,
            &'a mut Vec<f32>,
            &'a mut Frame,
            &'a mut Result<()>,
        );
        let mut slots: Vec<Slot<'_>> = chains
            .iter_mut()
            .zip(rtilde_w.iter_mut())
            .zip(frames.iter_mut())
            .zip(results.iter_mut())
            .map(|(((chain, buf), frame), res)| (chain, buf, frame, res))
            .collect();
        let min_items = crate::util::parallel::gate_by_dim(d);
        crate::util::parallel::par_for_each_indexed(&mut slots, min_items, |_wid, slot| {
            let (chain, buf, frame, res) = slot;
            if frame.kind == FrameKind::Update {
                // decode with the WORKER's round tag (shared-mask formats
                // seed from it); moving the payload out skips a byte copy
                let payload = frame.take_payload();
                **res = chain.receive(&payload, frame.round, buf.as_mut_slice());
            }
        });
    }
    for (wid, res) in results.into_iter().enumerate() {
        res.with_context(|| format!("round {round}: decode worker {wid}"))?;
    }
    Ok(())
}

/// The single frame-accounting policy, shared by both aggregation modes:
/// book an Update's rate/loss/per-block bits (the chain must already have
/// decoded it), count a Skip, reject anything else.
fn account_frame(
    frame: &Frame,
    wid: usize,
    chain: &dyn MasterScheme,
    comm: &mut CommStats,
    train_loss: &mut LossMeter,
) -> Result<()> {
    match frame.kind {
        FrameKind::Update => {
            comm.record_message(frame.payload_bits);
            train_loss.push(frame.loss as f64);
            for bb in chain.last_block_bits() {
                comm.record_block(&bb.name, bb.bits, bb.components);
            }
        }
        FrameKind::Skip => comm.record_skip(),
        other => anyhow::bail!("unexpected {other:?} frame from worker {wid}"),
    }
    Ok(())
}

/// Decode each worker's queued FIFO batch for this round — sequential
/// within a worker (the chain is a stateful delay line), parallel across
/// workers — into pooled per-frame r̃ scratch (`scratch[wid][k]` holds the
/// decoded r̃ of `batches[wid][k]`). Each Update's per-block `(bits,
/// components)` are snapshotted into `snaps[wid][k]` at decode time: the
/// chain's live `last_block_bits` only reflects its *final* frame of the
/// round, but accounting must replay per frame. Pools grow to the
/// high-water frame count and are reused across rounds. Decode failures
/// surface in worker-id order with the same context the sequential path
/// attached.
fn decode_batches_parallel(
    chains: &mut [Box<dyn MasterScheme>],
    batches: &mut [Vec<Frame>],
    scratch: &mut [Vec<Vec<f32>>],
    snaps: &mut [Vec<Vec<(u64, usize)>>],
    round: u64,
    d: usize,
) -> Result<()> {
    let n = batches.len();
    let mut results: Vec<Result<()>> = Vec::with_capacity(n);
    results.resize_with(n, || Ok(()));
    {
        type Slot<'a> = (
            &'a mut Box<dyn MasterScheme>,
            &'a mut Vec<Frame>,
            &'a mut Vec<Vec<f32>>,
            &'a mut Vec<Vec<(u64, usize)>>,
            &'a mut Result<()>,
        );
        let mut slots: Vec<Slot<'_>> = chains
            .iter_mut()
            .zip(batches.iter_mut())
            .zip(scratch.iter_mut())
            .zip(snaps.iter_mut())
            .zip(results.iter_mut())
            .map(|((((chain, batch), bufs), snap), res)| (chain, batch, bufs, snap, res))
            .collect();
        let min_items = crate::util::parallel::gate_by_dim(d);
        crate::util::parallel::par_for_each_indexed(&mut slots, min_items, |_wid, slot| {
            let (chain, batch, bufs, snap, res) = slot;
            for (k, frame) in batch.iter_mut().enumerate() {
                if bufs.len() <= k {
                    bufs.push(vec![0.0f32; d]);
                }
                if snap.len() <= k {
                    snap.push(Vec::new());
                }
                snap[k].clear();
                if frame.kind != FrameKind::Update {
                    continue;
                }
                // decode with the WORKER's round tag (shared-mask formats
                // seed from it), which under staleness differs from the
                // master round; the payload moves out (no byte copy)
                let payload = frame.take_payload();
                if let Err(e) = chain.receive(&payload, frame.round, bufs[k].as_mut_slice()) {
                    **res = Err(e);
                    break;
                }
                snap[k].extend(chain.last_block_bits().iter().map(|bb| (bb.bits, bb.components)));
            }
        });
    }
    for (wid, res) in results.into_iter().enumerate() {
        res.with_context(|| format!("round {round}: decode worker {wid}"))?;
    }
    Ok(())
}

/// [`account_frame`] for a batch-decoded frame: per-block bits/components
/// come from the decode-time snapshot, names from the chain (block
/// structure is fixed at scheme construction, so the chain's final-frame
/// names apply to every frame of the batch).
fn account_decoded(
    frame: &Frame,
    wid: usize,
    chain: &dyn MasterScheme,
    snap: &[(u64, usize)],
    comm: &mut CommStats,
    train_loss: &mut LossMeter,
) -> Result<()> {
    match frame.kind {
        FrameKind::Update => {
            comm.record_message(frame.payload_bits);
            train_loss.push(frame.loss as f64);
            let blocks = chain.last_block_bits();
            anyhow::ensure!(
                blocks.len() == snap.len(),
                "per-block accounting drift for worker {wid}"
            );
            for (bb, &(bits, components)) in blocks.iter().zip(snap.iter()) {
                comm.record_block(&bb.name, bits, components);
            }
        }
        FrameKind::Skip => comm.record_skip(),
        other => anyhow::bail!("unexpected {other:?} frame from worker {wid}"),
    }
    Ok(())
}

/// Mean loss / accuracy over `batches` held-out batches.
pub fn evaluate(
    model: &ModelExec,
    w: &[f32],
    test: &TestStream,
    batches: usize,
    salt: u64,
) -> Result<(f64, f64)> {
    let mut loss_sum = 0.0;
    let mut acc = AccuracyMeter::new();
    for i in 0..batches.max(1) {
        let batch = test.batch(&model.entry, i, salt);
        let (l, ncorr) = model.evaluate(w, &batch)?;
        loss_sum += l;
        acc.push(ncorr, model.eval_denominator());
    }
    Ok((loss_sum / batches.max(1) as f64, acc.accuracy()))
}
