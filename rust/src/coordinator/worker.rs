//! Worker-side loop.
//!
//! Owns: a data shard, a thread-confined PJRT runtime (model fwd/bwd and,
//! for the HLO backend, the compress artifact), the Eq.-(1) pipeline state,
//! and its replica of the parameter vector. Per round:
//!
//! 1. fetch a batch from the shard
//! 2. (loss, g) = PJRT fwd/bwd                         [phase "gradient"]
//! 3. pipeline step (momentum/EF/predict/quantize)     [phase "compress"]
//! 4. entropy-encode ũ and send to the master          [phase "encode"]
//! 5. receive the averaged r̃ broadcast, apply w-update [phase "apply"]
//!
//! Phases 2-4 are what the paper's Fig. 1 times per iteration.

use anyhow::{Context, Result};

use crate::coding::encode_payload;
use crate::comm::{Frame, WorkerTransport};
use crate::compress::{SchemeCfg, WorkerPipeline};
use crate::config::experiment::Backend;
use crate::data::{Batch, Dataset, Shard};
use crate::optim::LrSchedule;
use crate::runtime::{CompressExec, ModelExec, Runtime};
use crate::util::timer::{PhaseTimes, Timer};

/// What a worker thread returns when the run completes.
#[derive(Clone, Debug)]
pub struct WorkerSummary {
    pub worker_id: u32,
    pub rounds: u64,
    pub phases: PhaseTimes,
    pub mean_loss_last_quarter: f64,
    /// trace of per-round (1/d)‖e_t‖² (Fig. 5 / Fig. 8 right panel)
    pub e_mse_trace: Vec<f64>,
    /// trace of ‖u_t‖² (prediction-effect diagnostics)
    pub u_norm_trace: Vec<f64>,
}

/// Worker configuration (plain data; crosses the thread boundary).
#[derive(Clone, Debug)]
pub struct WorkerSpec {
    pub worker_id: u32,
    pub model: String,
    pub scheme: SchemeCfg,
    pub backend: Backend,
    pub schedule: LrSchedule,
    pub steps: u64,
    pub seed: u64,
    /// Clip the gradient to this global l2 norm before Eq. (1a) (None = off).
    pub clip_norm: Option<f32>,
}

/// The worker loop body. Generic over transport so channel and TCP runs
/// share the exact same code path.
pub struct WorkerLoop<T: WorkerTransport> {
    spec: WorkerSpec,
    transport: T,
    shard: Shard,
    dataset: std::sync::Arc<dyn Dataset>,
}

impl<T: WorkerTransport> WorkerLoop<T> {
    pub fn new(
        spec: WorkerSpec,
        transport: T,
        shard: Shard,
        dataset: std::sync::Arc<dyn Dataset>,
    ) -> Self {
        Self { spec, transport, shard, dataset }
    }

    /// Run `steps` synchronous rounds. Creates the PJRT runtime inside the
    /// calling thread (PJRT objects are not Send).
    pub fn run(mut self, runtime: &Runtime) -> Result<WorkerSummary> {
        let spec = self.spec.clone();
        let model = ModelExec::load(runtime, &spec.model)
            .with_context(|| format!("worker {}: load model", spec.worker_id))?;
        let d = model.entry.d;
        let mut w = runtime.manifest.load_init(&model.entry)?;
        let mut pipeline = WorkerPipeline::new(spec.scheme.clone(), d);
        let hlo_backend = match spec.backend {
            Backend::Rust => None,
            Backend::Hlo => Some(CompressExec::for_pipeline(runtime, &pipeline)?),
        };
        let payload_kind = spec.scheme.payload_kind();

        let mut phases = PhaseTimes::new();
        let mut e_mse_trace = Vec::with_capacity(spec.steps as usize);
        let mut u_norm_trace = Vec::with_capacity(spec.steps as usize);
        let mut losses = Vec::with_capacity(spec.steps as usize);
        let mut update = vec![0.0f32; d];

        for t in 0..spec.steps {
            // 1-2. gradient
            let indices = self.shard.next_indices();
            let batch: Batch = self.dataset.batch(&indices);
            let timer = Timer::start();
            let (loss, mut g) = model.fwdbwd(&w, &batch)?;
            phases.add("gradient", timer.elapsed_secs());
            if let Some(max_norm) = spec.clip_norm {
                let norm = crate::tensor::norm2(&g) as f32;
                if norm > max_norm {
                    crate::tensor::scale(&mut g, max_norm / norm);
                }
            }
            anyhow::ensure!(
                loss.is_finite(),
                "worker {}: loss diverged (non-finite) at round {t} — lower the \
                 learning rate or add warmup",
                spec.worker_id
            );
            losses.push(loss);

            // 3. compression pipeline (Eq. (1))
            let lr_ratio = lr_ratio(&spec.schedule, t);
            let timer = Timer::start();
            let stats = match &hlo_backend {
                Some(exec) => exec.step(&mut pipeline, &g, lr_ratio)?,
                None => pipeline.step(&g, lr_ratio),
            };
            phases.add("compress", timer.elapsed_secs());
            e_mse_trace.push(stats.e_mse);
            u_norm_trace.push(stats.u_norm_sq);

            // 4. encode + send
            let timer = Timer::start();
            let payload = encode_payload(payload_kind, pipeline.utilde(), t);
            phases.add("encode", timer.elapsed_secs());
            self.transport
                .send_update(Frame::update(spec.worker_id, t, payload, loss as f32))?;

            // 5. receive averaged r̃, apply update
            let frame = self.transport.recv_broadcast()?;
            let timer = Timer::start();
            let avg = frame.broadcast_f32(d)?;
            let lr = spec.schedule.lr_at(t);
            for i in 0..d {
                update[i] = avg[i];
                w[i] -= lr * update[i];
            }
            phases.add("apply", timer.elapsed_secs());
        }

        let q = (losses.len() / 4).max(1);
        let tail = &losses[losses.len() - q..];
        Ok(WorkerSummary {
            worker_id: spec.worker_id,
            rounds: spec.steps,
            phases,
            mean_loss_last_quarter: tail.iter().sum::<f64>() / tail.len() as f64,
            e_mse_trace,
            u_norm_trace,
        })
    }
}

/// η_{t-1}/η_t with the paper's η_{-1} = 0 convention.
pub fn lr_ratio(schedule: &LrSchedule, t: u64) -> f32 {
    if t == 0 {
        0.0
    } else {
        schedule.lr_at(t - 1) / schedule.lr_at(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_ratio_convention() {
        let s = LrSchedule::constant(0.1);
        assert_eq!(lr_ratio(&s, 0), 0.0);
        assert_eq!(lr_ratio(&s, 5), 1.0);
        let dec = LrSchedule::step_decay(1.0, 0.1, 10);
        assert!((lr_ratio(&dec, 10) - 10.0).abs() < 1e-4);
    }
}
