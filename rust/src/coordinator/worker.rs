//! Worker-side loop.
//!
//! Per round:
//!
//! 1. pull (loss, gradient) from the [`GradSource`]          [phase "gradient"]
//! 2. scheme pipeline step (momentum/EF/predict/quantize)    [phase "compress"]
//! 3. entropy-encode ũ and send to the master                [phase "encode"]
//! 4. receive the averaged r̃ broadcast, apply w-update       [phase "apply"]
//!
//! Phases 1-3 are what the paper's Fig. 1 times per iteration.
//!
//! The gradient source is injectable: the production path wraps a
//! thread-confined PJRT model (shard → fwd/bwd), while tests and synthetic
//! workloads plug in any closure — which is what lets the full coordinator
//! round loop (including blockwise schemes) run without artifacts.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::comm::{Frame, WorkerTransport};
use crate::config::experiment::Backend;
use crate::data::{Batch, Dataset, Shard};
use crate::optim::LrSchedule;
use crate::runtime::{CompressExec, ModelExec, Runtime};
use crate::scheme::{Scheme, WorkerScheme};
use crate::util::timer::{PhaseTimes, Timer};

/// What a worker thread returns when the run completes.
#[derive(Clone, Debug)]
pub struct WorkerSummary {
    pub worker_id: u32,
    pub rounds: u64,
    pub phases: PhaseTimes,
    pub mean_loss_last_quarter: f64,
    /// trace of per-round (1/d)‖e_t‖² (Fig. 5 / Fig. 8 right panel)
    pub e_mse_trace: Vec<f64>,
    /// trace of ‖u_t‖² (prediction-effect diagnostics)
    pub u_norm_trace: Vec<f64>,
}

/// Worker configuration (plain data; crosses the thread boundary).
#[derive(Clone, Debug)]
pub struct WorkerSpec {
    pub worker_id: u32,
    pub model: String,
    pub scheme: Scheme,
    pub backend: Backend,
    pub schedule: LrSchedule,
    pub steps: u64,
    pub seed: u64,
    /// Clip the gradient to this global l2 norm before Eq. (1a) (None = off).
    pub clip_norm: Option<f32>,
}

/// Produces (loss, gradient) at the current parameters for round t.
/// Implemented for any `FnMut(&[f32], u64) -> Result<(f64, Vec<f32>)>`.
pub trait GradSource {
    /// Untimed data-pipeline work (shard indexing, batch materialization).
    /// Called before the round's "gradient" phase timer starts, so phase
    /// times measure compute only — matching the paper's Fig. 1 breakdown.
    fn prefetch(&mut self, _round: u64) {}

    fn next_grad(&mut self, w: &[f32], round: u64) -> Result<(f64, Vec<f32>)>;
}

impl<F> GradSource for F
where
    F: FnMut(&[f32], u64) -> Result<(f64, Vec<f32>)>,
{
    fn next_grad(&mut self, w: &[f32], round: u64) -> Result<(f64, Vec<f32>)> {
        self(w, round)
    }
}

/// PJRT-model gradient source: shard → synthesize batch (prefetch, untimed)
/// → fwd/bwd (timed). Thread-confined like the `ModelExec` it owns.
struct ModelSource {
    model: ModelExec,
    shard: Shard,
    dataset: Arc<dyn Dataset>,
    batch: Option<Batch>,
}

impl GradSource for ModelSource {
    fn prefetch(&mut self, _round: u64) {
        let indices = self.shard.next_indices();
        self.batch = Some(self.dataset.batch(&indices));
    }

    fn next_grad(&mut self, w: &[f32], _round: u64) -> Result<(f64, Vec<f32>)> {
        let batch = self.batch.take().context("model source: prefetch not called")?;
        self.model.fwdbwd(w, &batch)
    }
}

enum Body {
    /// PJRT model execution over a data shard (the production path).
    Model { shard: Shard, dataset: Arc<dyn Dataset> },
    /// Injected gradient source with explicit initial parameters.
    Source { source: Box<dyn GradSource>, init_w: Vec<f32> },
}

/// The worker loop body. Generic over transport so channel and TCP runs
/// share the exact same code path.
pub struct WorkerLoop<T: WorkerTransport> {
    spec: WorkerSpec,
    transport: T,
    body: Body,
}

impl<T: WorkerTransport> WorkerLoop<T> {
    /// Model-backed worker (requires a PJRT runtime at `run` time).
    pub fn new(
        spec: WorkerSpec,
        transport: T,
        shard: Shard,
        dataset: Arc<dyn Dataset>,
    ) -> Self {
        Self { spec, transport, body: Body::Model { shard, dataset } }
    }

    /// Worker over an injected gradient source (rust backend only; runs
    /// without PJRT via [`Self::run_local`]).
    pub fn with_source(
        spec: WorkerSpec,
        transport: T,
        source: Box<dyn GradSource>,
        init_w: Vec<f32>,
    ) -> Self {
        Self { spec, transport, body: Body::Source { source, init_w } }
    }

    /// Run `steps` synchronous rounds. Creates PJRT executables inside the
    /// calling thread (PJRT objects are not Send).
    pub fn run(self, runtime: &Runtime) -> Result<WorkerSummary> {
        let WorkerLoop { spec, transport, body } = self;
        match body {
            Body::Model { shard, dataset } => {
                let model = ModelExec::load(runtime, &spec.model)
                    .with_context(|| format!("worker {}: load model", spec.worker_id))?;
                let d = model.entry.d;
                let w = runtime.manifest.load_init(&model.entry)?;
                let hlo = match spec.backend {
                    Backend::Rust => None,
                    Backend::Hlo => Some(CompressExec::for_scheme(runtime, &spec.scheme, d)?),
                };
                let mut source = ModelSource { model, shard, dataset, batch: None };
                run_rounds(&spec, transport, &mut source, w, hlo)
            }
            Body::Source { mut source, init_w } => {
                anyhow::ensure!(
                    spec.backend == Backend::Rust,
                    "worker {}: injected gradient sources support the rust backend only",
                    spec.worker_id
                );
                run_rounds(&spec, transport, source.as_mut(), init_w, None)
            }
        }
    }

    /// Run without a PJRT runtime — only valid for source-backed workers.
    pub fn run_local(self) -> Result<WorkerSummary> {
        let WorkerLoop { spec, transport, body } = self;
        match body {
            Body::Source { mut source, init_w } => {
                anyhow::ensure!(
                    spec.backend == Backend::Rust,
                    "worker {}: injected gradient sources support the rust backend only",
                    spec.worker_id
                );
                run_rounds(&spec, transport, source.as_mut(), init_w, None)
            }
            Body::Model { .. } => anyhow::bail!(
                "worker {}: model-backed workers need a PJRT runtime (use run)",
                spec.worker_id
            ),
        }
    }
}

fn run_rounds<T: WorkerTransport>(
    spec: &WorkerSpec,
    mut transport: T,
    source: &mut dyn GradSource,
    mut w: Vec<f32>,
    hlo: Option<CompressExec>,
) -> Result<WorkerSummary> {
    let d = w.len();
    let mut wscheme = spec.scheme.worker(d)?;

    let mut phases = PhaseTimes::new();
    let mut e_mse_trace = Vec::with_capacity(spec.steps as usize);
    let mut u_norm_trace = Vec::with_capacity(spec.steps as usize);
    let mut losses = Vec::with_capacity(spec.steps as usize);
    let mut update = vec![0.0f32; d];

    for t in 0..spec.steps {
        // 1. gradient (data prep untimed; the phase measures compute only)
        source.prefetch(t);
        let timer = Timer::start();
        let (loss, mut g) = source.next_grad(&w, t)?;
        phases.add("gradient", timer.elapsed_secs());
        anyhow::ensure!(g.len() == d, "worker {}: gradient dim mismatch", spec.worker_id);
        if let Some(max_norm) = spec.clip_norm {
            let norm = crate::tensor::norm2(&g) as f32;
            if norm > max_norm {
                crate::tensor::scale(&mut g, max_norm / norm);
            }
        }
        anyhow::ensure!(
            loss.is_finite(),
            "worker {}: loss diverged (non-finite) at round {t} — lower the \
             learning rate or add warmup",
            spec.worker_id
        );
        losses.push(loss);

        // 2. compression pipeline (Eq. (1))
        let lr_ratio = lr_ratio(&spec.schedule, t);
        let timer = Timer::start();
        let stats = match &hlo {
            Some(exec) => {
                let pipe = wscheme
                    .as_pipeline_mut()
                    .context("HLO backend needs a single-scheme pipeline")?;
                exec.step(pipe, &g, lr_ratio)?
            }
            None => wscheme.step(&g, lr_ratio),
        };
        phases.add("compress", timer.elapsed_secs());
        e_mse_trace.push(stats.e_mse);
        u_norm_trace.push(stats.u_norm_sq);

        // 3. encode + send
        let timer = Timer::start();
        let payload = wscheme.encode(t);
        phases.add("encode", timer.elapsed_secs());
        transport.send_update(Frame::update(spec.worker_id, t, payload, loss as f32))?;

        // 4. receive averaged r̃, apply update
        let frame = transport.recv_broadcast()?;
        let timer = Timer::start();
        let avg = frame.broadcast_f32(d)?;
        let lr = spec.schedule.lr_at(t);
        for i in 0..d {
            update[i] = avg[i];
            w[i] -= lr * update[i];
        }
        phases.add("apply", timer.elapsed_secs());
    }

    let q = (losses.len() / 4).max(1);
    let tail = &losses[losses.len() - q..];
    Ok(WorkerSummary {
        worker_id: spec.worker_id,
        rounds: spec.steps,
        phases,
        mean_loss_last_quarter: tail.iter().sum::<f64>() / tail.len() as f64,
        e_mse_trace,
        u_norm_trace,
    })
}

/// η_{t-1}/η_t with the paper's η_{-1} = 0 convention.
pub fn lr_ratio(schedule: &LrSchedule, t: u64) -> f32 {
    if t == 0 {
        0.0
    } else {
        schedule.lr_at(t - 1) / schedule.lr_at(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_ratio_convention() {
        let s = LrSchedule::constant(0.1);
        assert_eq!(lr_ratio(&s, 0), 0.0);
        assert_eq!(lr_ratio(&s, 5), 1.0);
        let dec = LrSchedule::step_decay(1.0, 0.1, 10);
        assert!((lr_ratio(&dec, 10) - 10.0).abs() < 1e-4);
    }
}
