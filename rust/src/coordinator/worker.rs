//! Worker-side loop.
//!
//! Per round:
//!
//! 1. pull (loss, gradient) from the [`GradSource`]          [phase "gradient"]
//! 2. scheme pipeline step (momentum/EF/predict/quantize)    [phase "compress"]
//! 3. entropy-encode ũ and send to the master                [phase "encode"/"send"]
//! 4. receive the averaged r̃ broadcast, apply w-update       [phase "wait"/"apply"]
//!
//! Phases 1-3 are what the paper's Fig. 1 times per iteration.
//!
//! **Pipelined mode** (the default): step 3's send runs on a dedicated
//! thread behind a depth-1 queue ([`crate::comm::PipelinedSender`]), and
//! the data prefetch for round t+1 runs while round t's payload is still
//! on the wire. Frame content and per-connection order are unchanged, so
//! pipelined and inline runs are bit-identical — only the timing moves.
//!
//! **Churn injection**: rounds listed in `WorkerSpec::absent` simulate
//! this worker leaving the compute pool — no gradient, no pipeline
//! advance, a zero-byte [`Frame::skip`] marker upstream so the master
//! aggregates without us — while staying subscribed to broadcasts (the
//! parameter vector keeps tracking the master, which is what lets the
//! worker rejoin with a chain still in sync).
//!
//! The gradient source is injectable: the production path wraps a
//! thread-confined PJRT model (shard → fwd/bwd), while tests and synthetic
//! workloads plug in any closure — which is what lets the full coordinator
//! round loop (including blockwise schemes) run without artifacts.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coding::Payload;
use crate::comm::{Frame, PipelinedSender, WorkerTransport, ADAPT_TAG, SYNC_ROUND, SYNC_TAG};
use crate::config::experiment::Backend;
use crate::coordinator::membership::{bitmap_rank, WorkerMembership, MAX_FLEET};
use crate::data::{Batch, Dataset, Shard};
use crate::metrics::registry::{Histogram, Meter, SECS_BUCKETS};
use crate::optim::LrSchedule;
use crate::runtime::{CompressExec, ModelExec, Runtime};
use crate::scheme::{Scheme, WorkerScheme};
use crate::util::timer::{PhaseTimes, Timer};

/// What a worker thread returns when the run completes.
#[derive(Clone, Debug)]
pub struct WorkerSummary {
    pub worker_id: u32,
    pub rounds: u64,
    pub phases: PhaseTimes,
    pub mean_loss_last_quarter: f64,
    /// trace of per-round (1/d)‖e_t‖² (Fig. 5 / Fig. 8 right panel)
    pub e_mse_trace: Vec<f64>,
    /// trace of ‖u_t‖² (prediction-effect diagnostics)
    pub u_norm_trace: Vec<f64>,
    /// rounds this worker sat out (fabric churn injection)
    pub skipped_rounds: u64,
    /// whether sends actually ran on the pipelined background stage
    pub pipelined: bool,
}

/// Worker configuration (plain data; crosses the thread boundary).
#[derive(Clone, Debug)]
pub struct WorkerSpec {
    pub worker_id: u32,
    pub model: String,
    pub scheme: Scheme,
    pub backend: Backend,
    pub schedule: LrSchedule,
    pub steps: u64,
    pub seed: u64,
    /// Clip the gradient to this global l2 norm before Eq. (1a) (None = off).
    pub clip_norm: Option<f32>,
    /// Overlap encode+send of round t with the prefetch of round t+1.
    pub pipelined: bool,
    /// Half-open round ranges [a, b) this worker sits out (churn injection).
    pub absent: Vec<(u64, u64)>,
    /// Chaos crash injection: vanish silently before sending round `t`'s
    /// frame — no Leave, no completion marker, the connection just drops.
    /// With `membership` the elastic engine's liveness deadline notices
    /// and evicts at a boundary (DESIGN.md §10); on a fixed fleet the
    /// master fails after `dead_grace` — which the multi-tenant demux
    /// scopes to the one hosted run that lost the worker (DESIGN.md §11,
    /// pinned by `tests/multi_run.rs`).
    pub depart_at: Option<u64>,
    /// This process is a fresh incarnation re-dialing after a crash: even
    /// if the member bitmap still carries our bit, the seat belongs to the
    /// dead predecessor — fence it off (local demotion + a Leave) and
    /// re-enter through fresh admission, never by resuming a chain the
    /// master folded someone else's updates into.
    pub rejoin: bool,
    /// Elastic fleet membership (`[membership]` config): which fleet epochs
    /// this worker *seeks*. When set, the worker runs the elastic round
    /// loop — the master's broadcast bitmap is authoritative for actual
    /// membership; the plan only drives Join/Leave control frames. `None`
    /// keeps the fixed-fleet loop untouched.
    pub membership: Option<WorkerMembership>,
    /// Adaptive per-block rate control (`[adaptive]` config): when true,
    /// the worker runs the scheme-epoch loop — it adopts the master's
    /// [`ADAPT_TAG`] boundary broadcasts (absolute `w` plus the next
    /// epoch's spec), rebuilds its whole pipeline, and stamps every update
    /// with the epoch it coded under (DESIGN.md §8). `false` keeps the
    /// fixed-scheme loops untouched.
    pub adaptive: bool,
}

impl WorkerSpec {
    pub fn is_absent(&self, t: u64) -> bool {
        self.absent.iter().any(|&(a, b)| t >= a && t < b)
    }
}

/// Worker-side observability handle: the `worker.phase.*` histograms
/// (docs/OBSERVABILITY.md). [`WorkerObs::off`] — the default — is a
/// structural bypass: every probe branches on `None` with no atomic
/// traffic and no allocation, so uninstrumented workers are untouched
/// (DESIGN.md §12). Phase timers themselves predate observability (they
/// feed [`WorkerSummary::phases`] either way), so on/off runs read the
/// clock identically.
#[derive(Clone, Default)]
pub struct WorkerObs(Option<Arc<WorkerObsInner>>);

struct WorkerObsInner {
    gradient: Histogram,
    compress: Histogram,
    encode: Histogram,
    send: Histogram,
    wait: Histogram,
    apply: Histogram,
}

impl WorkerObs {
    /// Register the worker's phase vocabulary on `meter` (idempotent by
    /// name — all workers of a process share the cells).
    pub fn new(meter: &Meter) -> Self {
        let h = |name: &str, help: &str| meter.histogram(name, "s", help, &SECS_BUCKETS);
        Self(Some(Arc::new(WorkerObsInner {
            gradient: h("worker.phase.gradient_secs", "per round: forward/backward compute"),
            compress: h("worker.phase.compress_secs", "per round: compression pipeline step"),
            encode: h("worker.phase.encode_secs", "per round: entropy encode"),
            send: h(
                "worker.phase.send_secs",
                "per round: ship the update (pipelined runs record the stage total once)",
            ),
            wait: h("worker.phase.wait_secs", "per round: blocked on the broadcast"),
            apply: h("worker.phase.apply_secs", "per round: decode + apply the w update"),
        })))
    }

    /// The structural bypass (see type docs).
    pub fn off() -> Self {
        Self(None)
    }

    pub fn is_on(&self) -> bool {
        self.0.is_some()
    }

    fn phase(&self, name: &str, secs: f64) {
        let Some(o) = self.0.as_deref() else { return };
        let h = match name {
            "gradient" => &o.gradient,
            "compress" => &o.compress,
            "encode" => &o.encode,
            "send" => &o.send,
            "wait" => &o.wait,
            "apply" => &o.apply,
            _ => return,
        };
        h.observe(secs);
    }
}

/// Phase bookkeeping: the run-report accumulator plus (when observing) the
/// `worker.phase.*` histograms — one observe per `add`, so the metric
/// distribution matches the per-round timings the summary averages.
struct Phases {
    times: PhaseTimes,
    obs: WorkerObs,
}

impl Phases {
    fn new(obs: WorkerObs) -> Self {
        Self { times: PhaseTimes::new(), obs }
    }

    fn add(&mut self, name: &str, secs: f64) {
        self.obs.phase(name, secs);
        self.times.add(name, secs);
    }

    fn add_many(&mut self, name: &str, total_secs: f64, count: u64) {
        if count > 0 {
            // the pipelined send stage reports once per run: observe its
            // cumulative time as a single histogram sample
            self.obs.phase(name, total_secs);
        }
        self.times.add_many(name, total_secs, count);
    }
}

/// Produces (loss, gradient) at the current parameters for round t.
/// Implemented for any `FnMut(&[f32], u64) -> Result<(f64, Vec<f32>)>`.
pub trait GradSource {
    /// Untimed data-pipeline work (shard indexing, batch materialization).
    /// Called before the round's "gradient" phase timer starts, so phase
    /// times measure compute only — matching the paper's Fig. 1 breakdown.
    /// In pipelined mode this is also the work that overlaps the previous
    /// round's in-flight send.
    fn prefetch(&mut self, _round: u64) {}

    fn next_grad(&mut self, w: &[f32], round: u64) -> Result<(f64, Vec<f32>)>;

    /// Elastic membership: re-key the data partition for a changed fleet —
    /// this worker now holds partition position `rank` of `n_members`, as
    /// of fleet epoch `fleet_epoch` (DESIGN.md §7). Sources without a
    /// partition (injected closures) ignore it; [`Shard`]-backed sources
    /// re-derive their `(epoch, worker_id)`-keyed assignment.
    fn rekey(&mut self, _rank: usize, _n_members: usize, _fleet_epoch: u64) {}
}

impl<F> GradSource for F
where
    F: FnMut(&[f32], u64) -> Result<(f64, Vec<f32>)>,
{
    fn next_grad(&mut self, w: &[f32], round: u64) -> Result<(f64, Vec<f32>)> {
        self(w, round)
    }
}

/// PJRT-model gradient source: shard → synthesize batch (prefetch, untimed)
/// → fwd/bwd (timed). Thread-confined like the `ModelExec` it owns.
struct ModelSource {
    model: ModelExec,
    shard: Shard,
    dataset: Arc<dyn Dataset>,
    batch: Option<Batch>,
}

impl GradSource for ModelSource {
    fn prefetch(&mut self, _round: u64) {
        let indices = self.shard.next_indices();
        self.batch = Some(self.dataset.batch(&indices));
    }

    fn next_grad(&mut self, w: &[f32], _round: u64) -> Result<(f64, Vec<f32>)> {
        let batch = self.batch.take().context("model source: prefetch not called")?;
        self.model.fwdbwd(w, &batch)
    }

    fn rekey(&mut self, rank: usize, n_members: usize, fleet_epoch: u64) {
        self.shard.rekey(rank, n_members, fleet_epoch);
        self.batch = None; // any staged batch belongs to the old partition
    }
}

enum Body {
    /// PJRT model execution over a data shard (the production path).
    Model { shard: Shard, dataset: Arc<dyn Dataset> },
    /// Injected gradient source with explicit initial parameters.
    Source { source: Box<dyn GradSource>, init_w: Vec<f32> },
}

/// The worker loop body. Generic over transport so channel and TCP runs
/// share the exact same code path.
pub struct WorkerLoop<T: WorkerTransport> {
    spec: WorkerSpec,
    transport: T,
    body: Body,
    obs: WorkerObs,
}

impl<T: WorkerTransport> WorkerLoop<T> {
    /// Model-backed worker (requires a PJRT runtime at `run` time).
    pub fn new(
        spec: WorkerSpec,
        transport: T,
        shard: Shard,
        dataset: Arc<dyn Dataset>,
    ) -> Self {
        Self { spec, transport, body: Body::Model { shard, dataset }, obs: WorkerObs::off() }
    }

    /// Worker over an injected gradient source (rust backend only; runs
    /// without PJRT via [`Self::run_local`]).
    pub fn with_source(
        spec: WorkerSpec,
        transport: T,
        source: Box<dyn GradSource>,
        init_w: Vec<f32>,
    ) -> Self {
        Self { spec, transport, body: Body::Source { source, init_w }, obs: WorkerObs::off() }
    }

    /// Attach an observability handle (builder style): phase timings flow
    /// into the `worker.phase.*` histograms for this run. The default is
    /// [`WorkerObs::off`], the structural bypass.
    pub fn with_observer(mut self, obs: WorkerObs) -> Self {
        self.obs = obs;
        self
    }

    /// Run `steps` synchronous rounds. Creates PJRT executables inside the
    /// calling thread (PJRT objects are not Send).
    pub fn run(self, runtime: &Runtime) -> Result<WorkerSummary> {
        let WorkerLoop { spec, transport, body, obs } = self;
        match body {
            Body::Model { shard, dataset } => {
                let model = ModelExec::load(runtime, &spec.model)
                    .with_context(|| format!("worker {}: load model", spec.worker_id))?;
                let d = model.entry.d;
                let w = runtime.manifest.load_init(&model.entry)?;
                let hlo = match spec.backend {
                    Backend::Rust => None,
                    Backend::Hlo => Some(CompressExec::for_scheme(runtime, &spec.scheme, d)?),
                };
                let mut source = ModelSource { model, shard, dataset, batch: None };
                run_rounds(&spec, transport, &mut source, w, hlo, obs)
            }
            Body::Source { mut source, init_w } => {
                anyhow::ensure!(
                    spec.backend == Backend::Rust,
                    "worker {}: injected gradient sources support the rust backend only",
                    spec.worker_id
                );
                run_rounds(&spec, transport, source.as_mut(), init_w, None, obs)
            }
        }
    }

    /// Run without a PJRT runtime — only valid for source-backed workers.
    pub fn run_local(self) -> Result<WorkerSummary> {
        let WorkerLoop { spec, transport, body, obs } = self;
        match body {
            Body::Source { mut source, init_w } => {
                anyhow::ensure!(
                    spec.backend == Backend::Rust,
                    "worker {}: injected gradient sources support the rust backend only",
                    spec.worker_id
                );
                run_rounds(&spec, transport, source.as_mut(), init_w, None, obs)
            }
            Body::Model { .. } => anyhow::bail!(
                "worker {}: model-backed workers need a PJRT runtime (use run)",
                spec.worker_id
            ),
        }
    }
}

/// Outgoing update path: inline on the loop thread, or double-buffered on
/// the background sender stage.
enum SendStage {
    Inline,
    Pipelined(PipelinedSender),
}

fn run_rounds<T: WorkerTransport>(
    spec: &WorkerSpec,
    mut transport: T,
    source: &mut dyn GradSource,
    w: Vec<f32>,
    hlo: Option<CompressExec>,
    obs: WorkerObs,
) -> Result<WorkerSummary> {
    let result = run_rounds_inner(spec, &mut transport, source, w, hlo, obs);
    // liveness marker: a clean completion tells the master this endpoint
    // goes quiet on purpose; an error turns into a prompt master-side
    // "hung up" failure instead of a blocked round. Best-effort — the
    // master may already be gone. A chaos departure (`depart_at`) sends
    // nothing: the whole point is to vanish the way a crashed process
    // does, leaving the master's liveness deadline to notice.
    let marker = match &result {
        Ok(_) if spec.depart_at.is_some() => return result,
        Ok(_) => Frame::done(spec.worker_id),
        Err(_) => Frame::abort(spec.worker_id),
    };
    let _ = transport.send_update(marker);
    result
}

fn run_rounds_inner<T: WorkerTransport>(
    spec: &WorkerSpec,
    transport: &mut T,
    source: &mut dyn GradSource,
    mut w: Vec<f32>,
    hlo: Option<CompressExec>,
    obs: WorkerObs,
) -> Result<WorkerSummary> {
    if spec.adaptive {
        anyhow::ensure!(
            spec.membership.is_none(),
            "worker {}: [adaptive] does not compose with [membership]",
            spec.worker_id
        );
        return run_rounds_adaptive(spec, transport, source, w, hlo, obs);
    }
    if spec.membership.is_some() {
        return run_rounds_elastic(spec, transport, source, w, hlo, obs);
    }
    let d = w.len();
    let mut wscheme = spec.scheme.worker(d)?;

    // double-buffered send stage: fall back to inline sends when the
    // transport cannot split (frame content is identical either way)
    let mut stage = if spec.pipelined {
        match transport.split_sender() {
            Ok(sender) => SendStage::Pipelined(PipelinedSender::spawn(sender)),
            Err(_) => SendStage::Inline,
        }
    } else {
        SendStage::Inline
    };
    let pipelined = matches!(stage, SendStage::Pipelined(_));

    let mut phases = Phases::new(obs);
    let mut e_mse_trace = Vec::with_capacity(spec.steps as usize);
    let mut u_norm_trace = Vec::with_capacity(spec.steps as usize);
    let mut losses = Vec::with_capacity(spec.steps as usize);
    let mut update = vec![0.0f32; d];
    // one broadcast frame recycled across rounds: the transport receives
    // into its payload buffer (recv_broadcast_into), closing the last
    // receive-side allocation of the round loop
    let mut bframe = Frame::shutdown();
    let mut skipped = 0u64;
    let mut completed = 0u64;

    // the round loop runs in a closure so that EVERY exit path falls
    // through to retiring the send stage below — the caller writes a
    // liveness marker on this same connection afterwards, which must not
    // interleave with an in-flight background send
    #[allow(clippy::redundant_closure_call)]
    let loop_result = (|| -> Result<()> {
        // payload buffers ping-pong through the send stage: encode fills a
        // recycled buffer, the transport hands it back after the frame
        // ships, so steady-state rounds allocate nothing on this path
        let mut spare: Option<Vec<u8>> = None;
        source.prefetch(0);
        for t in 0..spec.steps {
            if spec.depart_at == Some(t) {
                // chaos crash: vanish before sending round t's frame — no
                // marker; dropping the connection IS the injection, and
                // the master's liveness deadline takes it from here
                break;
            }
            if spec.is_absent(t) {
                // churn: out of the compute pool this round — announce
                // with a skip marker, keep applying broadcasts so w stays
                // in sync
                skipped += 1;
                e_mse_trace.push(0.0);
                u_norm_trace.push(0.0);
                let skip = Frame::skip(spec.worker_id, t);
                send_frame(&mut stage, transport, &mut phases, skip)?;
                if t + 1 < spec.steps {
                    source.prefetch(t + 1);
                }
                recv_apply(spec, transport, &mut phases, &mut w, &mut update, &mut bframe, t)?;
                completed += 1;
                continue;
            }

            // 1. gradient (data prep untimed; the phase measures compute)
            let timer = Timer::start();
            let (loss, mut g) = source.next_grad(&w, t)?;
            phases.add("gradient", timer.elapsed_secs());
            anyhow::ensure!(g.len() == d, "worker {}: gradient dim mismatch", spec.worker_id);
            if let Some(max_norm) = spec.clip_norm {
                let norm = crate::tensor::norm2(&g) as f32;
                if norm > max_norm {
                    crate::tensor::scale(&mut g, max_norm / norm);
                }
            }
            anyhow::ensure!(
                loss.is_finite(),
                "worker {}: loss diverged (non-finite) at round {t} — lower the \
                 learning rate or add warmup",
                spec.worker_id
            );
            losses.push(loss);

            // 2. compression pipeline (Eq. (1))
            let lr_ratio = lr_ratio(&spec.schedule, t);
            let timer = Timer::start();
            let stats = match &hlo {
                Some(exec) => {
                    let pipe = wscheme
                        .as_pipeline_mut()
                        .context("HLO backend needs a single-scheme pipeline")?;
                    exec.step(pipe, &g, lr_ratio)?
                }
                None => wscheme.step(&g, lr_ratio),
            };
            phases.add("compress", timer.elapsed_secs());
            e_mse_trace.push(stats.e_mse);
            u_norm_trace.push(stats.u_norm_sq);

            // 3. encode into a recycled buffer, then ship (inline, or
            // handed to the sender thread)
            let timer = Timer::start();
            let mut payload = Payload::empty();
            if let Some(buf) = spare.take() {
                payload.bytes = buf;
            }
            wscheme.encode_into(t, &mut payload);
            phases.add("encode", timer.elapsed_secs());
            send_frame(
                &mut stage,
                transport,
                &mut phases,
                Frame::update(spec.worker_id, t, payload, loss as f32),
            )?;
            // pick up a spent buffer the transport handed back
            if let SendStage::Pipelined(sender) = &mut stage {
                if spare.is_none() {
                    spare = sender.take_spare();
                }
            }

            // overlap window: while round t's payload is on the wire,
            // stage the data for round t+1
            if t + 1 < spec.steps {
                source.prefetch(t + 1);
            }

            // 4. receive averaged r̃, apply update
            recv_apply(spec, transport, &mut phases, &mut w, &mut update, &mut bframe, t)?;
            completed += 1;
        }
        Ok(())
    })();

    // retire the send stage on every path (success or error) BEFORE the
    // caller touches the connection again; a send-path failure is the root
    // cause of any enqueue error the loop saw, so it wins
    let sender_result = match stage {
        SendStage::Pipelined(sender) => {
            let report = sender.finish();
            phases.add_many("send", report.send_secs, report.frames);
            report.result
        }
        SendStage::Inline => Ok(()),
    };
    // the "hung up" marker keeps launch-time triage preferring another
    // worker's substantive error (a dead master is usually a symptom)
    sender_result.with_context(|| {
        format!("worker {}: pipelined send failed (master hung up?)", spec.worker_id)
    })?;
    loop_result?;

    let mean_tail = if losses.is_empty() {
        0.0
    } else {
        let q = (losses.len() / 4).max(1);
        let tail = &losses[losses.len() - q..];
        tail.iter().sum::<f64>() / tail.len() as f64
    };
    Ok(WorkerSummary {
        worker_id: spec.worker_id,
        // spec.steps unless a chaos departure cut the loop short
        rounds: completed,
        phases: phases.times,
        mean_loss_last_quarter: mean_tail,
        e_mse_trace,
        u_norm_trace,
        skipped_rounds: skipped,
        pipelined,
    })
}

/// The elastic worker loop (`spec.membership` set): the fixed-fleet loop
/// promoted to epoch-phased membership (DESIGN.md §7). Sends are inline
/// only — membership transitions must observe broadcasts in lockstep with
/// sends, and the double-buffered stage would let a round-t+1 frame ship
/// before round t's sync broadcast has been folded into local state.
///
/// Per round `t` (from `start`, the round after this worker's first
/// received broadcast):
///
/// * **member** — the normal paper round (gradient → pipeline → encode →
///   send Update), except at the final round of the last sought epoch,
///   where a zero-payload Leave replaces the Update (that round's
///   contribution is forfeited; the master evicts at the boundary).
/// * **non-member** — no gradient, no pipeline: send Join when the plan
///   seeks the next epoch (the master parks us until its boundary tick),
///   else Skip. The parameter vector is only tracked once a membership
///   sync has been adopted (`w_valid`): delta broadcasts against an
///   unknown base are ignored, which is safe precisely because
///   non-members contribute nothing.
/// * **broadcast handling** — [`SYNC_TAG`] broadcasts carry the absolute
///   post-round parameters plus the member bitmap: adopt both. Plain
///   broadcasts apply the usual `w -= η·r̃` delta. On a bitmap change the
///   worker re-keys its data partition to its new `(rank, n_members)`
///   position; on its own admission it additionally rebuilds the scheme
///   chain from scratch — the worker half of the chain-reset contract
///   (the master rebuilt its decode chain at the same tick).
fn run_rounds_elastic<T: WorkerTransport>(
    spec: &WorkerSpec,
    transport: &mut T,
    source: &mut dyn GradSource,
    mut w: Vec<f32>,
    hlo: Option<CompressExec>,
    obs: WorkerObs,
) -> Result<WorkerSummary> {
    let plan = spec.membership.as_ref().expect("dispatched on membership");
    let wid = spec.worker_id;
    anyhow::ensure!(
        spec.absent.is_empty(),
        "worker {wid}: elastic membership and churn injection are mutually exclusive"
    );
    anyhow::ensure!(plan.admit_at >= 1, "worker {wid}: [membership] admit_at must be >= 1");
    anyhow::ensure!(
        (wid as usize) < MAX_FLEET,
        "worker {wid}: elastic membership supports worker ids below {MAX_FLEET}"
    );
    let bit = 1u64 << wid;
    let d = w.len();
    let mut wscheme = spec.scheme.worker(d)?;
    let mut stage = SendStage::Inline;

    let mut phases = Phases::new(obs);
    let mut e_mse_trace = Vec::with_capacity(spec.steps as usize);
    let mut u_norm_trace = Vec::with_capacity(spec.steps as usize);
    let mut losses = Vec::with_capacity(spec.steps as usize);
    let mut update = vec![0.0f32; d];
    let mut bframe = Frame::shutdown();
    let mut skipped = 0u64;

    // prologue: every elastic worker receives one broadcast before its
    // first send — the pre-round-0 beacon at launch, or (for a connection
    // joining mid-run) whatever broadcast first reaches it. That is what
    // tells us the current member bitmap and our first round, and is the
    // master's half of the no-deadlock roster contract.
    let timer = Timer::start();
    transport.recv_broadcast_into(&mut bframe)?;
    phases.add("wait", timer.elapsed_secs());
    let mut bitmap = bframe.payload_bits;
    let mut member = bitmap & bit != 0;
    let mut w_valid = false;
    if bframe.payload_tag == SYNC_TAG {
        bframe.broadcast_f32_into(&mut w)?;
        w_valid = true;
    }
    let start = if bframe.round == SYNC_ROUND { 0 } else { bframe.round + 1 };
    let mut stale_member = false;
    if member && (!w_valid || spec.rejoin) {
        // generation fence: the bitmap still carries our bit from a
        // previous incarnation (this connection re-dialed before the
        // master's deadline or boundary noticed the old one die) — and
        // either way a re-joining process must not resume that seat: the
        // master's decode chain holds the predecessor's state, ours is
        // fresh. Demote locally and announce the stale slot's departure on
        // the first round; the master evicts it at the boundary and this
        // incarnation re-enters as a fresh admission with a fresh chain.
        member = false;
        stale_member = true;
    }
    if member {
        if let Some((rank, n_members)) = bitmap_rank(bitmap, wid as usize) {
            // no-op when (rank, n_members, epoch key) match the shard's
            // static launch values — the static-fleet bypass path
            source.rekey(rank, n_members, start / plan.admit_at);
        }
        if start < spec.steps {
            source.prefetch(start);
        }
    }

    for t in start..spec.steps {
        if spec.depart_at == Some(t) {
            // chaos crash: vanish before sending round t's frame — the
            // caller drops the connection without ceremony and the
            // master's liveness deadline takes it from here
            break;
        }
        let epoch = t / plan.admit_at;
        let boundary = (t + 1) % plan.admit_at == 0;
        let leaving = member && boundary && !plan.wants(epoch + 1);
        if member && !leaving {
            // 1. gradient (data prep untimed; the phase measures compute)
            let timer = Timer::start();
            let (loss, mut g) = source.next_grad(&w, t)?;
            phases.add("gradient", timer.elapsed_secs());
            anyhow::ensure!(g.len() == d, "worker {wid}: gradient dim mismatch");
            if let Some(max_norm) = spec.clip_norm {
                let norm = crate::tensor::norm2(&g) as f32;
                if norm > max_norm {
                    crate::tensor::scale(&mut g, max_norm / norm);
                }
            }
            anyhow::ensure!(
                loss.is_finite(),
                "worker {wid}: loss diverged (non-finite) at round {t} — lower the \
                 learning rate or add warmup"
            );
            losses.push(loss);

            // 2. compression pipeline (Eq. (1))
            let lr_ratio = lr_ratio(&spec.schedule, t);
            let timer = Timer::start();
            let stats = match &hlo {
                Some(exec) => {
                    let pipe = wscheme
                        .as_pipeline_mut()
                        .context("HLO backend needs a single-scheme pipeline")?;
                    exec.step(pipe, &g, lr_ratio)?
                }
                None => wscheme.step(&g, lr_ratio),
            };
            phases.add("compress", timer.elapsed_secs());
            e_mse_trace.push(stats.e_mse);
            u_norm_trace.push(stats.u_norm_sq);

            // 3. encode and ship
            let timer = Timer::start();
            let mut payload = Payload::empty();
            wscheme.encode_into(t, &mut payload);
            phases.add("encode", timer.elapsed_secs());
            send_frame(
                &mut stage,
                transport,
                &mut phases,
                Frame::update(wid, t, payload, loss as f32),
            )?;
        } else {
            // sitting this round out: a member announcing departure
            // forfeits its final round's contribution; a non-member sends
            // Join while it seeks the next epoch (the master parks the
            // request until its boundary tick), else Skip
            skipped += 1;
            e_mse_trace.push(0.0);
            u_norm_trace.push(0.0);
            let frame = if member || stale_member {
                // a live member departing — or a stale slot from a prior
                // incarnation being fenced off (see the prologue)
                stale_member = false;
                Frame::leave(wid, t)
            } else if plan.wants(epoch + 1) {
                Frame::join(wid, t)
            } else {
                Frame::skip(wid, t)
            };
            send_frame(&mut stage, transport, &mut phases, frame)?;
        }

        // 4. receive broadcast t: adopt a sync, apply a delta
        let timer = Timer::start();
        transport.recv_broadcast_into(&mut bframe)?;
        phases.add("wait", timer.elapsed_secs());
        anyhow::ensure!(
            bframe.round == t,
            "worker {wid}: broadcast skew: got {} during round {t}",
            bframe.round
        );
        let timer = Timer::start();
        let new_bitmap = bframe.payload_bits;
        if bframe.payload_tag == SYNC_TAG {
            bframe.broadcast_f32_into(&mut w)?;
            w_valid = true;
        } else if w_valid {
            bframe.broadcast_f32_into(&mut update)?;
            let lr = spec.schedule.lr_at(t);
            for i in 0..d {
                w[i] -= lr * update[i];
            }
        }
        phases.add("apply", timer.elapsed_secs());

        // membership transition (bitmap only changes at boundary syncs)
        let was_member = member;
        member = new_bitmap & bit != 0;
        if member && !was_member {
            anyhow::ensure!(
                bframe.payload_tag == SYNC_TAG,
                "worker {wid}: admitted outside a membership sync broadcast"
            );
            // chain-reset contract: our freshly built chain mirrors the
            // master's rebuilt decode chain for us at this same boundary
            wscheme = spec.scheme.worker(d)?;
        }
        if member && (new_bitmap != bitmap || !was_member) {
            let (rank, n_members) = bitmap_rank(new_bitmap, wid as usize)
                .expect("member bit verified above");
            source.rekey(rank, n_members, (t + 1) / plan.admit_at);
        }
        bitmap = new_bitmap;
        if member && t + 1 < spec.steps {
            source.prefetch(t + 1);
        }
    }

    let mean_tail = if losses.is_empty() {
        0.0
    } else {
        let q = (losses.len() / 4).max(1);
        let tail = &losses[losses.len() - q..];
        tail.iter().sum::<f64>() / tail.len() as f64
    };
    Ok(WorkerSummary {
        worker_id: wid,
        rounds: spec.steps,
        phases: phases.times,
        mean_loss_last_quarter: mean_tail,
        e_mse_trace,
        u_norm_trace,
        skipped_rounds: skipped,
        pipelined: false,
    })
}

/// The adaptive worker loop (`spec.adaptive` set): the fixed-fleet loop
/// promoted to negotiated scheme epochs (DESIGN.md §8). Sends are inline
/// only — the epoch a round-t+1 update must be stamped with is decided by
/// the round-t broadcast, so the double-buffered send stage (which lets a
/// round-t+1 frame ship before round t's broadcast is folded into local
/// state) cannot be used; `spec.pipelined` is ignored here.
///
/// Broadcast handling: an [`ADAPT_TAG`] broadcast is a scheme-epoch switch
/// — adopt the absolute post-round parameters, parse the carried spec,
/// rebuild the whole pipeline from it, and stamp all further updates with
/// the frame's (new) epoch. This is the worker half of the fleet-wide
/// chain-reset contract (the master rebuilt every decode chain at the same
/// boundary), and it is what makes the epoch-switch identity hold: from
/// the switch on, the run is bit-identical to a fresh run started from the
/// synced `w` with the new spec. Plain broadcasts apply the usual
/// `w -= η·r̃` delta.
fn run_rounds_adaptive<T: WorkerTransport>(
    spec: &WorkerSpec,
    transport: &mut T,
    source: &mut dyn GradSource,
    mut w: Vec<f32>,
    hlo: Option<CompressExec>,
    obs: WorkerObs,
) -> Result<WorkerSummary> {
    let wid = spec.worker_id;
    anyhow::ensure!(
        hlo.is_none(),
        "worker {wid}: the HLO compress backend cannot rebuild its compiled pipeline at a \
         scheme-epoch switch — use the rust backend with [adaptive]"
    );
    let d = w.len();
    let mut wscheme = spec.scheme.worker(d)?;
    let mut epoch: u16 = 0;
    let mut stage = SendStage::Inline;

    let mut phases = Phases::new(obs);
    let mut e_mse_trace = Vec::with_capacity(spec.steps as usize);
    let mut u_norm_trace = Vec::with_capacity(spec.steps as usize);
    let mut losses = Vec::with_capacity(spec.steps as usize);
    let mut update = vec![0.0f32; d];
    let mut bframe = Frame::shutdown();
    let mut skipped = 0u64;

    source.prefetch(0);
    for t in 0..spec.steps {
        if spec.is_absent(t) {
            // churn: out of the compute pool this round, but broadcasts —
            // including scheme switches — are still adopted below
            skipped += 1;
            e_mse_trace.push(0.0);
            u_norm_trace.push(0.0);
            let skip = Frame::skip(wid, t).with_scheme_epoch(epoch);
            send_frame(&mut stage, transport, &mut phases, skip)?;
        } else {
            // 1. gradient (data prep untimed; the phase measures compute)
            let timer = Timer::start();
            let (loss, mut g) = source.next_grad(&w, t)?;
            phases.add("gradient", timer.elapsed_secs());
            anyhow::ensure!(g.len() == d, "worker {wid}: gradient dim mismatch");
            if let Some(max_norm) = spec.clip_norm {
                let norm = crate::tensor::norm2(&g) as f32;
                if norm > max_norm {
                    crate::tensor::scale(&mut g, max_norm / norm);
                }
            }
            anyhow::ensure!(
                loss.is_finite(),
                "worker {wid}: loss diverged (non-finite) at round {t} — lower the \
                 learning rate or add warmup"
            );
            losses.push(loss);

            // 2. compression pipeline (Eq. (1))
            let lr_ratio = lr_ratio(&spec.schedule, t);
            let timer = Timer::start();
            let stats = wscheme.step(&g, lr_ratio);
            phases.add("compress", timer.elapsed_secs());
            e_mse_trace.push(stats.e_mse);
            u_norm_trace.push(stats.u_norm_sq);

            // 3. encode and ship, tagged with the epoch we coded under —
            // the master rejects a mismatch instead of mis-decoding
            let timer = Timer::start();
            let mut payload = Payload::empty();
            wscheme.encode_into(t, &mut payload);
            phases.add("encode", timer.elapsed_secs());
            let frame = Frame::update(wid, t, payload, loss as f32).with_scheme_epoch(epoch);
            send_frame(&mut stage, transport, &mut phases, frame)?;
        }

        if t + 1 < spec.steps {
            source.prefetch(t + 1);
        }

        // 4. receive broadcast t: adopt a scheme switch, or apply a delta
        let timer = Timer::start();
        transport.recv_broadcast_into(&mut bframe)?;
        phases.add("wait", timer.elapsed_secs());
        anyhow::ensure!(
            bframe.round == t,
            "worker {wid}: broadcast skew: got {} during round {t}",
            bframe.round
        );
        let timer = Timer::start();
        if bframe.payload_tag == ADAPT_TAG {
            let next = {
                let spec_str = bframe.sync_scheme_parts(&mut w)?;
                Scheme::parse(spec_str)
                    .with_context(|| format!("worker {wid}: scheme-epoch switch at round {t}"))?
            };
            // whole-pipeline rebuild: momentum, EF and predictor state
            // restart from zero, exactly as a fresh run would start
            wscheme = next.worker(d)?;
            epoch = bframe.scheme_epoch;
        } else {
            bframe.broadcast_f32_into(&mut update)?;
            let lr = spec.schedule.lr_at(t);
            for i in 0..d {
                w[i] -= lr * update[i];
            }
        }
        phases.add("apply", timer.elapsed_secs());
    }

    let mean_tail = if losses.is_empty() {
        0.0
    } else {
        let q = (losses.len() / 4).max(1);
        let tail = &losses[losses.len() - q..];
        tail.iter().sum::<f64>() / tail.len() as f64
    };
    Ok(WorkerSummary {
        worker_id: wid,
        rounds: spec.steps,
        phases: phases.times,
        mean_loss_last_quarter: mean_tail,
        e_mse_trace,
        u_norm_trace,
        skipped_rounds: skipped,
        pipelined: false,
    })
}

fn send_frame<T: WorkerTransport>(
    stage: &mut SendStage,
    transport: &mut T,
    phases: &mut Phases,
    frame: Frame,
) -> Result<()> {
    match stage {
        SendStage::Inline => {
            let timer = Timer::start();
            transport.send_update(frame)?;
            phases.add("send", timer.elapsed_secs());
            Ok(())
        }
        SendStage::Pipelined(sender) => sender.enqueue(frame),
    }
}

fn recv_apply<T: WorkerTransport>(
    spec: &WorkerSpec,
    transport: &mut T,
    phases: &mut Phases,
    w: &mut [f32],
    update: &mut [f32],
    bframe: &mut Frame,
    t: u64,
) -> Result<()> {
    let timer = Timer::start();
    // receive into the recycled frame: TCP reads the body into the frame's
    // existing buffer, the channel fabric ships the spent buffer back to
    // the master's broadcast staging (see comm module docs)
    transport.recv_broadcast_into(bframe)?;
    phases.add("wait", timer.elapsed_secs());
    let timer = Timer::start();
    // decode straight into the recycled dense update buffer — together
    // with the master's broadcast_from staging this closes the broadcast
    // side of the round loop's allocation story (ROADMAP)
    bframe.broadcast_f32_into(update)?;
    let lr = spec.schedule.lr_at(t);
    for i in 0..w.len() {
        w[i] -= lr * update[i];
    }
    phases.add("apply", timer.elapsed_secs());
    Ok(())
}

/// η_{t-1}/η_t with the paper's η_{-1} = 0 convention.
pub fn lr_ratio(schedule: &LrSchedule, t: u64) -> f32 {
    if t == 0 {
        0.0
    } else {
        schedule.lr_at(t - 1) / schedule.lr_at(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_ratio_convention() {
        let s = LrSchedule::constant(0.1);
        assert_eq!(lr_ratio(&s, 0), 0.0);
        assert_eq!(lr_ratio(&s, 5), 1.0);
        let dec = LrSchedule::step_decay(1.0, 0.1, 10);
        assert!((lr_ratio(&dec, 10) - 10.0).abs() < 1e-4);
    }

    #[test]
    fn absent_windows_are_half_open() {
        let spec = WorkerSpec {
            worker_id: 0,
            model: "synthetic".into(),
            scheme: Scheme::parse("none").unwrap(),
            backend: Backend::Rust,
            schedule: LrSchedule::constant(0.1),
            steps: 10,
            seed: 0,
            clip_norm: None,
            pipelined: true,
            absent: vec![(2, 4), (7, 8)],
            depart_at: None,
            rejoin: false,
            membership: None,
            adaptive: false,
        };
        let absent: Vec<u64> = (0..10).filter(|&t| spec.is_absent(t)).collect();
        assert_eq!(absent, vec![2, 3, 7]);
    }
}
