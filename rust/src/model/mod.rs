//! Artifact manifest + model zoo.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every AOT
//! artifact (models and compression steps). This module parses it into
//! plain-data structs (Send + Sync, shareable across worker threads —
//! unlike the PJRT objects, which stay thread-confined in [`crate::runtime`]).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::{json, Value};

/// Model kinds the coordinator knows how to feed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Classifier,
    Lm,
}

/// One model entry from the manifest.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    /// flat parameter dimension
    pub d: usize,
    pub batch: usize,
    pub kind: ModelKind,
    /// classifier: input dim (e.g. 3072) / classes; lm: vocab / seq
    pub in_dim: usize,
    pub classes: usize,
    pub vocab: usize,
    pub seq: usize,
    pub fwdbwd_file: String,
    pub eval_file: String,
    pub init_file: String,
}

/// One compression-step artifact entry.
#[derive(Clone, Debug)]
pub struct CompressEntry {
    pub name: String,
    pub file: String,
    pub d: usize,
    pub quantizer: String,
    pub predictor: String,
    pub ef: bool,
    pub beta: f64,
    pub k: usize,
    pub randk_prob: f64,
}

/// Parsed artifacts/manifest.json plus the directory it lives in.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelEntry>,
    pub compress: Vec<CompressEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Default location: ./artifacts (or $TEMPO_ARTIFACTS).
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("TEMPO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let v = json::parse(text).context("parse manifest.json")?;
        let mut models = Vec::new();
        for m in v.get("models")?.as_array()? {
            let kind = match m.get("kind")?.as_str()? {
                "lm" => ModelKind::Lm,
                _ => ModelKind::Classifier,
            };
            models.push(ModelEntry {
                name: m.get("name")?.as_str()?.to_string(),
                d: m.get("d")?.as_usize()?,
                batch: m.get("batch")?.as_usize()?,
                kind,
                in_dim: opt_usize(m, "in_dim"),
                classes: opt_usize(m, "classes"),
                vocab: opt_usize(m, "vocab"),
                seq: opt_usize(m, "seq"),
                fwdbwd_file: m.get("fwdbwd")?.as_str()?.to_string(),
                eval_file: m.get("eval")?.as_str()?.to_string(),
                init_file: m.get("init")?.as_str()?.to_string(),
            });
        }
        let mut compress = Vec::new();
        for c in v.get("compress")?.as_array()? {
            compress.push(CompressEntry {
                name: c.get("name")?.as_str()?.to_string(),
                file: c.get("file")?.as_str()?.to_string(),
                d: c.get("d")?.as_usize()?,
                quantizer: c.get("quantizer")?.as_str()?.to_string(),
                predictor: c.get("predictor")?.as_str()?.to_string(),
                ef: c.get("ef")?.as_bool()?,
                beta: c.get("beta")?.as_f64()?,
                k: c.get("k")?.as_usize()?,
                randk_prob: c.get("randk_prob")?.as_f64()?,
            });
        }
        Ok(Self { dir, models, compress })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .with_context(|| {
                let names: Vec<&str> = self.models.iter().map(|m| m.name.as_str()).collect();
                format!("model {name:?} not in manifest (have: {names:?})")
            })
    }

    /// Find a compress artifact matching a scheme at dimension d.
    pub fn find_compress(
        &self,
        d: usize,
        quantizer: &str,
        predictor: &str,
        ef: bool,
    ) -> Option<&CompressEntry> {
        self.compress
            .iter()
            .find(|c| c.d == d && c.quantizer == quantizer && c.predictor == predictor && c.ef == ef)
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Load a model's initial flat parameter vector (raw f32 LE bytes).
    pub fn load_init(&self, model: &ModelEntry) -> Result<Vec<f32>> {
        let path = self.artifact_path(&model.init_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("read init params {}", path.display()))?;
        anyhow::ensure!(
            bytes.len() == model.d * 4,
            "init file {} has {} bytes, expected {} (d={})",
            path.display(),
            bytes.len(),
            model.d * 4,
            model.d
        );
        let mut out = vec![0.0f32; model.d];
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(out)
    }
}

fn opt_usize(v: &Value, key: &str) -> usize {
    v.opt(key).and_then(|x| x.as_usize().ok()).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": [
        {"name": "cnn_s", "d": 11642, "batch": 32, "kind": "classifier",
         "in_dim": 3072, "classes": 10,
         "fwdbwd": "model_cnn_s_fwdbwd.hlo.txt",
         "eval": "model_cnn_s_eval.hlo.txt", "init": "init_cnn_s.bin"},
        {"name": "lm_tiny", "d": 21952, "batch": 8, "kind": "lm",
         "vocab": 64, "seq": 32,
         "fwdbwd": "f.hlo.txt", "eval": "e.hlo.txt", "init": "i.bin"}
      ],
      "compress": [
        {"name": "c1", "file": "c1.hlo.txt", "d": 1024, "quantizer": "topk",
         "predictor": "estk", "ef": true, "beta": 0.9, "k": 32, "randk_prob": 0.0}
      ]
    }"#;

    #[test]
    fn parses_models_and_compress() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.models.len(), 2);
        let cnn = m.model("cnn_s").unwrap();
        assert_eq!(cnn.d, 11642);
        assert_eq!(cnn.kind, ModelKind::Classifier);
        let lm = m.model("lm_tiny").unwrap();
        assert_eq!(lm.kind, ModelKind::Lm);
        assert_eq!(lm.vocab, 64);
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn compress_lookup() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.find_compress(1024, "topk", "estk", true).is_some());
        assert!(m.find_compress(1024, "topk", "estk", false).is_none());
        assert!(m.find_compress(999, "topk", "estk", true).is_none());
    }
}
