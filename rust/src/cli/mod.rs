//! Command-line interface (in-repo arg parser; offline build has no clap).
//!
//! Grammar: `tempo <subcommand> [--flag value]... [--switch]... [--] [pos]...`
//! `--key=value` and `--key value` both work; everything after a bare `--`
//! is positional. A `--flag` followed by another `--token` is recorded as a
//! switch — and because the parser is schema-less it cannot know a value
//! was intended, so the typed accessors (`flag`, `usize_flag`, ...) report
//! an error instead of silently falling back to the default (use
//! `--flag=value` for values that start with `-`).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: subcommand + flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (exclusive of argv[0]). If the
    /// first argument is a `--flag` there is no subcommand (example binaries
    /// take flags only).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut it = args.into_iter().peekable();
        let subcommand = match it.peek() {
            Some(first) if first.starts_with("--") => String::new(),
            _ => it.next().unwrap_or_else(|| "help".to_string()),
        };
        let mut out = Args { subcommand, ..Default::default() };
        let mut only_positional = false;
        while let Some(a) = it.next() {
            if only_positional {
                out.positional.push(a);
                continue;
            }
            if a == "--" {
                // end-of-flags separator: the rest is positional verbatim
                only_positional = true;
                continue;
            }
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| n.starts_with("--")).unwrap_or(true) {
                    // next token is absent, the separator, or another flag:
                    // record a switch (see module docs for the error path)
                    out.switches.push(rest.to_string());
                } else {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Value of `--name`. Errors when `--name` was given but its value got
    /// parsed as a switch (the next argument started with `--`).
    pub fn flag(&self, name: &str) -> Result<Option<&str>> {
        if let Some(v) = self.flags.get(name) {
            return Ok(Some(v.as_str()));
        }
        if self.switches.iter().any(|s| s == name) {
            bail!(
                "flag --{name} requires a value but none was consumed (the next \
                 argument started with '--'); write --{name}=<value> instead"
            );
        }
        Ok(None)
    }

    pub fn flag_or(&self, name: &str, default: &str) -> Result<String> {
        Ok(self.flag(name)?.unwrap_or(default).to_string())
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name)? {
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer")),
            None => Ok(default),
        }
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name)? {
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer")),
            None => Ok(default),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name)? {
            Some(v) => v.parse().with_context(|| format!("--{name} must be a number")),
            None => Ok(default),
        }
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// key=value overrides after the known flags (e.g. `--set.scheme.beta 0.9`).
    pub fn overrides(&self) -> Vec<(String, String)> {
        self.flags
            .iter()
            .filter(|(k, _)| k.starts_with("set."))
            .map(|(k, v)| (k["set.".len()..].to_string(), v.clone()))
            .collect()
    }
}

pub const USAGE: &str = "\
tempo — temporal-correlation gradient compression for momentum-SGD
(Adikari & Draper, IEEE JSAIT 2021 — three-layer rust/JAX/Pallas reproduction)

USAGE:
  tempo train --config <file.toml> [--steps N] [--workers N] [--backend rust|hlo]
              [--scheme <spec>] [--fabric <spec>] [--io threads|reactor]
              [--shards N] [--membership <spec>] [--adaptive <spec>] [--runs R]
              [--trace <spec>] [--csv out.csv]
  tempo exp <id> [--smoke] [--out results/]   run a paper experiment:
        table1 | fig1 | fig3 | fig4 | fig5 | fig6 | fig7 | fig8 | theorem1 |
        fabric | ablation-beta | ablation-block | ablation-master | all
  tempo inspect                                list artifacts from the manifest
  tempo metrics-dump --file <snapshot.json>    render an end-of-run metrics
                                               snapshot (<csv>.metrics.json)
  tempo master-serve --listen <addr:port> --workers N --config <file.toml>
  tempo worker-connect --connect <addr:port> --worker-id I --config <file.toml>
  tempo help

Master sharding (--shards N or the [shards] config table; DESIGN.md §4):
  the master splits by the scheme's blocks(...) partition — shard s owns a
  subset of blocks and aggregates its slice of w. Over TCP, shard s serves
  on listen-port + s and workers dial every shard; shards=1 is bit-identical
  to the unsharded master. [shards] assign = "emb:0;rest:1" pins blocks.

Scheme spec strings (see DESIGN.md for the grammar → paper Eq. (1) mapping):
  topk:k_frac=0.0024/estk/ef/beta=0.99        Table I bottom row
  sign/plin/beta=0.99                         scaled-sign with prediction
  blocks(emb=0.25:topk:k=64/estk/ef;rest=0.75:sign/plin)   blockwise composite

Fabric spec tokens (--fabric, comma-separated; see DESIGN.md §2/§6):
  channel | tcp                 transport (default channel; tcp = real sockets)
  threads | reactor             master I/O over tcp (default reactor = single-
                                threaded epoll loop, O(1) master threads, bounded
                                broadcast write queues; threads = one blocking
                                reader thread per connection; --io is sugar)
  io_queue=N                    reactor per-connection write-queue bound (frames)
  pipelined | inline            double-buffered vs blocking sends (default pipelined)
  staleness=S,quorum=Q          bounded-staleness aggregation (S=0 ⇒ full sync)
  straggler=W:MS[;W:MS]         per-worker pre-send delay in ms
  drop=P,retransmit_ms=T        drop-and-retransmit injection
  churn=W:A..B[;...]            worker W absent for rounds [A, B)
  dead_grace=S                  liveness deadline in seconds (default 2): a member
                                silent this long is staged for eviction at the
                                next fleet-epoch boundary (DESIGN.md §10)
  chaos=W:KIND:A..B[;...]       injected fault for worker W over rounds [A, B):
                                wedge (alive but silent), crash (abrupt close +
                                backoff re-join), halfopen (crash behind a held-
                                open socket); crash/halfopen need tcp
  e.g.  --fabric tcp,staleness=2,quorum=2,straggler=1:5,drop=0.01,churn=3:10..20
  e.g.  --fabric tcp,dead_grace=0.5,chaos=1:wedge:4..999

Elastic membership (--membership or the [membership] table; DESIGN.md §7):
  min=N,max=N,admit=R           epoch-phased coordinator: workers join/leave at
                                fleet-epoch boundaries (every R rounds); joins
                                park as pending until the boundary, admissions
                                get fresh prediction chains + re-keyed shards;
                                a fleet dipping below min parks in the Holding
                                phase until quorum returns (DESIGN.md §10)
  e.g.  --membership min=2,max=4,admit=8

Adaptive rate control (--adaptive or the [adaptive] table; DESIGN.md §8):
  target=B,window=R,hysteresis=H
                                online per-block rate controller: every R
                                rounds the master re-rates the scheme's
                                blocks toward B payload bits/component and
                                announces the next scheme epoch (absolute w
                                + new spec) in a boundary broadcast; H is
                                the no-flap deadband. Rust backend only;
                                not composable with --shards/--membership
  e.g.  --adaptive target=2.5,window=8,hysteresis=0.1

Multi-tenant hosting (--runs R or the [runs] table; DESIGN.md §11):
  one master process hosts R independent runs on one fabric and one
  thread: run r owns workers [r*N, (r+1)*N), trains with seed+r, and is
  bit-identical to launching it solo. Every frame carries a run_id;
  cross-run delivery is a protocol error, and one run's failure leaves
  its siblings running. --runs 1 (default) bypasses the demux entirely.
  Not composable with --shards/--membership/--adaptive or crash chaos.
  e.g.  --runs 8

Observability (--trace or the [trace] table; DESIGN.md §12, docs/OBSERVABILITY.md):
  on | off                      master switch (default off — the structural
                                bypass: no registry, no ring, no clock reads;
                                bit- and alloc-identical to an untraced run)
  path=FILE                     drain the structured event ring to JSONL
  ring=N                        event-ring capacity (default 4096; overflow
                                drops the oldest event and counts it)
  Composes with every feature. With --csv set, the end-of-run registry
  snapshot lands at <csv>.metrics.json (read it with metrics-dump).
  e.g.  --trace path=run.trace.jsonl,ring=8192

Artifacts are read from ./artifacts (override with TEMPO_ARTIFACTS).
Run `make artifacts` first to lower the JAX/Pallas graphs.
Tier-1 CI entry point: scripts/ci.sh (fmt, clippy, build, test).
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --config x.toml --steps 100 --smoke");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.flag("config").unwrap(), Some("x.toml"));
        assert_eq!(a.u64_flag("steps", 0).unwrap(), 100);
        assert!(a.has_switch("smoke"));
        assert!(!a.has_switch("other"));
    }

    #[test]
    fn equals_form_and_positional() {
        let a = parse("exp fig6 --out=results --beta 0.99");
        assert_eq!(a.subcommand, "exp");
        assert_eq!(a.positional(), &["fig6".to_string()]);
        assert_eq!(a.flag("out").unwrap(), Some("results"));
        assert!((a.f64_flag("beta", 0.0).unwrap() - 0.99).abs() < 1e-12);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --steps abc");
        assert!(a.u64_flag("steps", 0).is_err());
    }

    #[test]
    fn empty_defaults_to_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.subcommand, "help");
    }

    #[test]
    fn swallowed_flag_value_is_an_error_not_a_silent_default() {
        // `--steps --smoke`: the user almost certainly forgot the value; the
        // old parser silently used the default. Typed lookups now error.
        let a = parse("train --steps --smoke");
        assert!(a.has_switch("steps"));
        assert!(a.has_switch("smoke"));
        let e = a.u64_flag("steps", 7).unwrap_err();
        assert!(format!("{e:#}").contains("--steps=<value>"), "{e:#}");
        assert!(a.flag("steps").is_err());
        assert!(a.flag_or("steps", "x").is_err());
        // flags that were never mentioned still default cleanly
        assert_eq!(a.u64_flag("workers", 4).unwrap(), 4);
        assert_eq!(a.flag("workers").unwrap(), None);
    }

    #[test]
    fn dashed_values_work_via_equals_form() {
        let a = parse("pr --note=--draft --title=a=b --empty=");
        assert_eq!(a.flag("note").unwrap(), Some("--draft"));
        // only the first '=' splits key from value
        assert_eq!(a.flag("title").unwrap(), Some("a=b"));
        assert_eq!(a.flag("empty").unwrap(), Some(""));
    }

    #[test]
    fn single_dash_values_are_consumed() {
        // negative numbers are ordinary values
        let a = parse("train --lr -0.5 --offset -3");
        assert!((a.f64_flag("lr", 0.0).unwrap() + 0.5).abs() < 1e-12);
        assert_eq!(a.flag("offset").unwrap(), Some("-3"));
    }

    #[test]
    fn double_dash_ends_flag_parsing() {
        let a = parse("run --steps 3 -- --not-a-flag pos --x=y");
        assert_eq!(a.u64_flag("steps", 0).unwrap(), 3);
        assert_eq!(
            a.positional(),
            &["--not-a-flag".to_string(), "pos".to_string(), "--x=y".to_string()]
        );
        assert!(!a.has_switch("not-a-flag"));
    }

    #[test]
    fn trailing_flag_is_a_switch() {
        let a = parse("run --verbose");
        assert!(a.has_switch("verbose"));
        // and `--flag --` (separator next) is a switch too
        let a = parse("run --verbose -- x");
        assert!(a.has_switch("verbose"));
        assert_eq!(a.positional(), &["x".to_string()]);
    }

    #[test]
    fn overrides_pass_through() {
        let a = parse("train --set.scheme.beta 0.9 --set.lr.base 0.1");
        let mut o = a.overrides();
        o.sort();
        assert_eq!(
            o,
            vec![
                ("lr.base".to_string(), "0.1".to_string()),
                ("scheme.beta".to_string(), "0.9".to_string())
            ]
        );
    }
}
