//! Command-line interface (in-repo arg parser; offline build has no clap).
//!
//! Grammar: `tempo <subcommand> [--flag value]... [--switch]...`
//! Unknown flags are errors; `--key=value` and `--key value` both work.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: subcommand + flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (exclusive of argv[0]). If the
    /// first argument is a `--flag` there is no subcommand (example binaries
    /// take flags only).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut it = args.into_iter().peekable();
        let subcommand = match it.peek() {
            Some(first) if first.starts_with("--") => String::new(),
            _ => it.next().unwrap_or_else(|| "help".to_string()),
        };
        let mut out = Args { subcommand, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare -- not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.switches.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer")),
            None => Ok(default),
        }
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer")),
            None => Ok(default),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} must be a number")),
            None => Ok(default),
        }
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// key=value overrides after the known flags (e.g. `--set scheme.beta=0.9`).
    pub fn overrides(&self) -> Vec<(String, String)> {
        self.flags
            .iter()
            .filter(|(k, _)| k.starts_with("set."))
            .map(|(k, v)| (k["set.".len()..].to_string(), v.clone()))
            .collect()
    }
}

pub const USAGE: &str = "\
tempo — temporal-correlation gradient compression for momentum-SGD
(Adikari & Draper, IEEE JSAIT 2021 — three-layer rust/JAX/Pallas reproduction)

USAGE:
  tempo train --config <file.toml> [--steps N] [--workers N] [--backend rust|hlo] [--csv out.csv]
  tempo exp <id> [--smoke] [--out results/]   run a paper experiment:
        table1 | fig1 | fig3 | fig4 | fig5 | fig6 | fig7 | fig8 | theorem1 |
        ablation-beta | ablation-block | ablation-master | all
  tempo inspect                                list artifacts from the manifest
  tempo master-serve --listen <addr:port> --workers N --config <file.toml>
  tempo worker-connect --connect <addr:port> --worker-id I --config <file.toml>
  tempo help

Artifacts are read from ./artifacts (override with TEMPO_ARTIFACTS).
Run `make artifacts` first to lower the JAX/Pallas graphs.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --config x.toml --steps 100 --smoke");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.flag("config"), Some("x.toml"));
        assert_eq!(a.u64_flag("steps", 0).unwrap(), 100);
        assert!(a.has_switch("smoke"));
        assert!(!a.has_switch("other"));
    }

    #[test]
    fn equals_form_and_positional() {
        let a = parse("exp fig6 --out=results --beta 0.99");
        assert_eq!(a.subcommand, "exp");
        assert_eq!(a.positional(), &["fig6".to_string()]);
        assert_eq!(a.flag("out"), Some("results"));
        assert!((a.f64_flag("beta", 0.0).unwrap() - 0.99).abs() < 1e-12);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --steps abc");
        assert!(a.u64_flag("steps", 0).is_err());
    }

    #[test]
    fn empty_defaults_to_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.subcommand, "help");
    }
}
