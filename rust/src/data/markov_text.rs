//! Seeded Markov-chain text corpus for the LM example.
//!
//! An order-1 Markov chain over `vocab` tokens with a sparse, Zipf-flavoured
//! transition structure. Sample i is a length-(seq+1) walk whose start state
//! and randomness derive from (seed, i) — index-addressable like the image
//! set, so sharding is exact. The chain has real sequential structure (each
//! state strongly prefers a few successors), so an LM's loss drops well
//! below the uniform-entropy baseline as it learns the transitions.

use super::{Batch, Dataset};
use crate::util::Pcg64;

#[derive(Clone, Debug)]
pub struct MarkovCorpus {
    pub vocab: usize,
    pub seq: usize,
    pub train_len: usize,
    seed: u64,
    /// per-state cumulative transition distribution (vocab × vocab)
    cdf: Vec<f64>,
}

impl MarkovCorpus {
    pub fn new(vocab: usize, seq: usize, train_len: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0x7E47);
        let mut cdf = vec![0.0f64; vocab * vocab];
        for s in 0..vocab {
            // each state gets ~5 preferred successors with Zipf weights,
            // plus a small uniform floor for ergodicity
            let mut probs = vec![0.02 / vocab as f64; vocab];
            let n_pref = 3 + rng.below(5) as usize;
            for r in 0..n_pref {
                let succ = rng.below(vocab as u64) as usize;
                probs[succ] += 0.98 / ((r + 1) as f64 * (1..=n_pref).map(|j| 1.0 / j as f64).sum::<f64>());
            }
            let total: f64 = probs.iter().sum();
            let mut acc = 0.0;
            for t in 0..vocab {
                acc += probs[t] / total;
                cdf[s * vocab + t] = acc;
            }
            cdf[s * vocab + vocab - 1] = 1.0;
        }
        Self { vocab, seq, train_len, seed, cdf }
    }

    /// Generate the i-th (tokens, targets) window.
    pub fn window(&self, index: usize, x: &mut [i32], y: &mut [i32]) {
        debug_assert_eq!(x.len(), self.seq);
        debug_assert_eq!(y.len(), self.seq);
        let mut rng = Pcg64::new(self.seed ^ 0x3A11, index as u64);
        let mut state = rng.below(self.vocab as u64) as usize;
        for t in 0..=self.seq {
            if t < self.seq {
                x[t] = state as i32;
            }
            if t > 0 {
                y[t - 1] = state as i32;
            }
            let row = &self.cdf[state * self.vocab..(state + 1) * self.vocab];
            state = rng.categorical_cdf(row);
        }
    }

    /// Entropy rate upper bound: log2(vocab) — for loss-sanity checks.
    pub fn uniform_nats(&self) -> f64 {
        (self.vocab as f64).ln()
    }
}

impl Dataset for MarkovCorpus {
    fn len(&self) -> usize {
        self.train_len
    }

    fn batch(&self, indices: &[usize]) -> Batch {
        let b = indices.len();
        let mut x = vec![0i32; b * self.seq];
        let mut y = vec![0i32; b * self.seq];
        for (row, &idx) in indices.iter().enumerate() {
            let (xs, ys) = (
                &mut x[row * self.seq..(row + 1) * self.seq],
                &mut y[row * self.seq..(row + 1) * self.seq],
            );
            // windows wrap within train_len so epochs revisit data
            self.window_wrapped(idx, xs, ys);
        }
        Batch::Tokens { x, y, batch: b }
    }

    fn label_space(&self) -> usize {
        self.vocab
    }
}

impl MarkovCorpus {
    fn window_wrapped(&self, index: usize, x: &mut [i32], y: &mut [i32]) {
        let idx = if self.train_len > 0 { index % self.train_len } else { index };
        self.window(idx, x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_deterministic_and_shifted() {
        let c = MarkovCorpus::new(32, 16, 1000, 5);
        let mut x1 = vec![0; 16];
        let mut y1 = vec![0; 16];
        let mut x2 = vec![0; 16];
        let mut y2 = vec![0; 16];
        c.window(3, &mut x1, &mut y1);
        c.window(3, &mut x2, &mut y2);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        // y is x shifted by one within the walk
        assert_eq!(&x1[1..], &y1[..15]);
    }

    #[test]
    fn tokens_in_vocab() {
        let c = MarkovCorpus::new(8, 32, 100, 9);
        match c.batch(&[0, 1, 2]) {
            Batch::Tokens { x, y, batch } => {
                assert_eq!(batch, 3);
                assert!(x.iter().all(|&t| (0..8).contains(&t)));
                assert!(y.iter().all(|&t| (0..8).contains(&t)));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn chain_has_predictable_structure() {
        // empirical conditional entropy must be well below log2(vocab)
        let vocab = 32;
        let c = MarkovCorpus::new(vocab, 64, 10_000, 11);
        let mut counts = vec![0u64; vocab * vocab];
        let mut x = vec![0; 64];
        let mut y = vec![0; 64];
        for i in 0..200 {
            c.window(i, &mut x, &mut y);
            for t in 0..64 {
                counts[x[t] as usize * vocab + y[t] as usize] += 1;
            }
        }
        let mut cond_h = 0.0;
        let total: u64 = counts.iter().sum();
        for s in 0..vocab {
            let row = &counts[s * vocab..(s + 1) * vocab];
            let row_total: u64 = row.iter().sum();
            if row_total == 0 {
                continue;
            }
            let h = crate::util::entropy_from_counts(row);
            cond_h += (row_total as f64 / total as f64) * h;
        }
        let uniform = (vocab as f64).log2();
        assert!(
            cond_h < 0.7 * uniform,
            "conditional entropy {cond_h:.2} vs uniform {uniform:.2}"
        );
    }
}
