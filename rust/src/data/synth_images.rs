//! Synthetic ImageNet-32 stand-in.
//!
//! Each class c has a fixed Gaussian prototype μ_c ∈ R^{3072}; sample i of
//! class (i mod C) is μ_c + σ·ε with ε re-derived from (seed, i) — so the
//! dataset is infinite-index deterministic, needs no storage, and keeps the
//! unimodal/symmetric gradient statistics the paper's quantizers rely on
//! (Sec. IV-B, refs [6],[20],[21]). `difficulty` (σ/signal ratio) controls
//! how separable the classes are so learning curves have dynamic range.

use super::{Batch, Dataset};
use crate::util::Pcg64;

#[derive(Clone, Debug)]
pub struct SynthImages {
    pub classes: usize,
    pub dim: usize,
    pub train_len: usize,
    pub test_len: usize,
    seed: u64,
    noise: f32,
    prototypes: Vec<f32>, // classes × dim
}

impl SynthImages {
    /// Standard configuration: 32×32×3 images.
    pub fn new(classes: usize, train_len: usize, test_len: usize, seed: u64, noise: f32) -> Self {
        let dim = 3 * 32 * 32;
        let mut proto_rng = Pcg64::new(seed, 0xC1A55);
        let mut prototypes = vec![0.0f32; classes * dim];
        // prototypes scaled so signal ~ unit energy per pixel
        proto_rng.fill_gaussian(&mut prototypes, 1.0);
        Self { classes, dim, train_len, test_len, seed, noise, prototypes }
    }

    /// Sample index → (pixels, label). Train indices are [0, train_len);
    /// test samples live at indices [2^40, 2^40 + test_len) so the streams
    /// never collide.
    pub fn sample_into(&self, index: usize, out: &mut [f32]) -> i32 {
        debug_assert_eq!(out.len(), self.dim);
        let label = (index % self.classes) as i32;
        let proto = &self.prototypes[label as usize * self.dim..(label as usize + 1) * self.dim];
        let mut rng = Pcg64::new(self.seed ^ 0x1A6E5, index as u64);
        for (o, &p) in out.iter_mut().zip(proto) {
            *o = p + self.noise * rng.gaussian() as f32;
        }
        label
    }

    const TEST_BASE: usize = 1 << 40;

    pub fn test_batch(&self, start: usize, batch: usize) -> Batch {
        let indices: Vec<usize> = (0..batch)
            .map(|i| Self::TEST_BASE + (start + i) % self.test_len.max(1))
            .collect();
        self.batch(&indices)
    }
}

impl Dataset for SynthImages {
    fn len(&self) -> usize {
        self.train_len
    }

    fn batch(&self, indices: &[usize]) -> Batch {
        let b = indices.len();
        let mut x = vec![0.0f32; b * self.dim];
        let mut y = vec![0i32; b];
        for (row, &idx) in indices.iter().enumerate() {
            y[row] = self.sample_into(idx, &mut x[row * self.dim..(row + 1) * self.dim]);
        }
        Batch::Image { x, y, batch: b }
    }

    fn label_space(&self) -> usize {
        self.classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_samples() {
        let ds = SynthImages::new(10, 1000, 100, 42, 0.5);
        let mut a = vec![0.0f32; ds.dim];
        let mut b = vec![0.0f32; ds.dim];
        let la = ds.sample_into(17, &mut a);
        let lb = ds.sample_into(17, &mut b);
        assert_eq!(a, b);
        assert_eq!(la, lb);
        assert_eq!(la, 7);
        let lc = ds.sample_into(18, &mut b);
        assert_eq!(lc, 8);
        assert_ne!(a, b);
    }

    #[test]
    fn batch_layout() {
        let ds = SynthImages::new(10, 1000, 100, 1, 0.5);
        let batch = ds.batch(&[0, 1, 2, 3]);
        match batch {
            Batch::Image { x, y, batch } => {
                assert_eq!(batch, 4);
                assert_eq!(x.len(), 4 * 3072);
                assert_eq!(y, vec![0, 1, 2, 3]);
            }
            _ => panic!("wrong batch kind"),
        }
    }

    #[test]
    fn classes_are_separable_at_low_noise() {
        // nearest-prototype classification on clean-ish samples must beat
        // chance comfortably — sanity that the task is learnable
        let ds = SynthImages::new(5, 100, 50, 7, 0.8);
        let mut correct = 0;
        let mut buf = vec![0.0f32; ds.dim];
        for i in 0..50 {
            let label = ds.sample_into(i, &mut buf);
            let mut best = (f64::INFINITY, -1i32);
            for c in 0..5 {
                let proto = &ds.prototypes[c * ds.dim..(c + 1) * ds.dim];
                let dist: f64 = buf
                    .iter()
                    .zip(proto)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, c as i32);
                }
            }
            correct += (best.1 == label) as i32;
        }
        assert!(correct >= 45, "nearest-prototype acc {correct}/50");
    }

    #[test]
    fn train_and_test_streams_disjoint() {
        let ds = SynthImages::new(10, 100, 10, 3, 0.5);
        let tr = ds.batch(&[0]);
        let te = ds.test_batch(0, 1);
        match (tr, te) {
            (Batch::Image { x: a, .. }, Batch::Image { x: b, .. }) => assert_ne!(a, b),
            _ => panic!(),
        }
    }
}
