//! Datasets: the paper's ImageNet-32 is substituted (see DESIGN.md §5) by a
//! deterministic synthetic 32×32×3 classification set with Gaussian class
//! prototypes, and the LM example trains on a seeded Markov-chain corpus.
//! Both are index-addressable (sample i is a pure function of (seed, i)), so
//! sharding across workers is exact and reproducible — the property the
//! paper gets from partitioning ImageNet into n equal training sets.

pub mod markov_text;
pub mod shard;
pub mod synth_images;

pub use markov_text::MarkovCorpus;
pub use shard::Shard;
pub use synth_images::SynthImages;

/// A batch in the layout the PJRT model artifacts expect.
#[derive(Clone, Debug)]
pub enum Batch {
    /// Images: x = f32[batch * in_dim] row-major, y = i32[batch].
    Image { x: Vec<f32>, y: Vec<i32>, batch: usize },
    /// LM: tokens/targets = i32[batch * seq] row-major.
    Tokens { x: Vec<i32>, y: Vec<i32>, batch: usize },
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        match self {
            Batch::Image { batch, .. } | Batch::Tokens { batch, .. } => *batch,
        }
    }

    pub fn labels(&self) -> &[i32] {
        match self {
            Batch::Image { y, .. } | Batch::Tokens { y, .. } => y,
        }
    }
}

/// Anything that can produce the i-th sample of a deterministic stream.
pub trait Dataset: Send + Sync {
    /// Number of distinct training samples (indices wrap beyond this).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Assemble a batch from explicit sample indices.
    fn batch(&self, indices: &[usize]) -> Batch;

    /// Label count (classes or vocab) — for accuracy normalization.
    fn label_space(&self) -> usize;
}
