//! Worker data sharding.
//!
//! The paper partitions the training set into n equal parts (Sec. VI). We
//! use strided assignment: worker w of n owns indices {w, w+n, w+2n, ...},
//! and each epoch reshuffles the *visit order* of the shard deterministically
//! from (seed, epoch) — every worker sees only its shard, every sample is
//! visited once per epoch.
//!
//! **Elastic membership** ([`crate::coordinator::membership`]): when the
//! fleet grows or shrinks at a fleet-epoch boundary, [`Shard::rekey`]
//! re-derives the partition from the worker's *member rank* — position
//! among the current members — instead of its launch-time worker id, with
//! the visit order re-keyed by `(seed, fleet_epoch, worker_id)` via
//! [`assignment_seed`]. Identical `(epoch, seed, member-set)` inputs
//! re-derive identical assignments on every replica (property-tested in
//! `tests/prop_coordinator.rs`), and a rekey to the launch values is a
//! no-op — the static-fleet bypass stays bit-identical.

use crate::coordinator::membership::assignment_seed;
use crate::util::Pcg64;

#[derive(Clone, Debug)]
pub struct Shard {
    pub worker: usize,
    pub n_workers: usize,
    pub dataset_len: usize,
    pub batch: usize,
    seed: u64,
    epoch: u64,
    /// Partition position: rank within the current member set. Launch
    /// default `(worker, n_workers)`; moved by [`Self::rekey`].
    rank: usize,
    n_ranks: usize,
    /// Fleet epoch of the last rekey (0 = the static launch partition).
    fleet_epoch: u64,
    order: Vec<usize>,
    cursor: usize,
}

impl Shard {
    pub fn new(worker: usize, n_workers: usize, dataset_len: usize, batch: usize, seed: u64) -> Self {
        assert!(worker < n_workers, "worker {worker} >= n_workers {n_workers}");
        assert!(batch > 0);
        let mut s = Self {
            worker,
            n_workers,
            dataset_len,
            batch,
            seed,
            epoch: 0,
            rank: worker,
            n_ranks: n_workers,
            fleet_epoch: 0,
            order: Vec::new(),
            cursor: 0,
        };
        s.reshuffle();
        s
    }

    /// Samples owned by this worker (its current partition position).
    pub fn shard_len(&self) -> usize {
        let d = self.dataset_len;
        let (n, r) = (self.n_ranks, self.rank);
        if d == 0 {
            0
        } else {
            (d - r + n - 1) / n
        }
    }

    /// Batches per epoch (floor — ragged tails are dropped like the usual
    /// drop_remainder=True input pipelines).
    pub fn batches_per_epoch(&self) -> usize {
        self.shard_len() / self.batch
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Re-key the partition for a changed fleet: this worker now holds
    /// position `rank` of `n_ranks` among the members, as of fleet epoch
    /// `fleet_epoch`. The data epoch restarts and the visit order is
    /// re-derived from `(seed, fleet_epoch, worker)` — deterministic given
    /// identical inputs on every replica. Re-keying to the current values
    /// (in particular the launch `(worker, n_workers, 0)`) is a no-op, so
    /// an unchurned elastic run consumes the exact same sample sequence as
    /// a static one.
    pub fn rekey(&mut self, rank: usize, n_ranks: usize, fleet_epoch: u64) {
        assert!(rank < n_ranks, "rank {rank} >= n_ranks {n_ranks}");
        if rank == self.rank && n_ranks == self.n_ranks && fleet_epoch == self.fleet_epoch {
            return;
        }
        self.rank = rank;
        self.n_ranks = n_ranks;
        self.fleet_epoch = fleet_epoch;
        self.epoch = 0;
        self.reshuffle();
    }

    fn reshuffle(&mut self) {
        self.order = (0..self.shard_len())
            .map(|j| self.rank + j * self.n_ranks)
            .collect();
        let base = assignment_seed(self.seed, self.fleet_epoch, self.worker);
        let mut rng = Pcg64::new(base ^ (self.epoch.wrapping_mul(0x9E37)), self.worker as u64);
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next batch of sample indices; rolls the epoch when exhausted.
    pub fn next_indices(&mut self) -> Vec<usize> {
        if self.cursor + self.batch > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let out = self.order[self.cursor..self.cursor + self.batch].to_vec();
        self.cursor += self.batch;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn shards_partition_dataset() {
        let n = 4;
        let len = 103;
        let mut seen = HashSet::new();
        let mut total = 0;
        for w in 0..n {
            let s = Shard::new(w, n, len, 1, 0);
            total += s.shard_len();
            for j in 0..s.shard_len() {
                assert!(seen.insert(w + j * n));
            }
        }
        assert_eq!(total, len);
        assert_eq!(seen.len(), len);
    }

    #[test]
    fn epoch_visits_each_sample_once() {
        let mut s = Shard::new(1, 3, 30, 2, 7);
        let mut seen = Vec::new();
        for _ in 0..s.batches_per_epoch() {
            seen.extend(s.next_indices());
        }
        seen.sort_unstable();
        let expect: Vec<usize> = (0..10).map(|j| 1 + 3 * j).collect();
        assert_eq!(seen, expect);
    }

    #[test]
    fn epoch_rolls_and_reshuffles() {
        let mut s = Shard::new(0, 1, 16, 4, 3);
        let mut first_epoch = Vec::new();
        for _ in 0..4 {
            first_epoch.push(s.next_indices());
        }
        assert_eq!(s.epoch(), 0);
        let b = s.next_indices(); // rolls into epoch 1
        assert_eq!(s.epoch(), 1);
        assert_eq!(b.len(), 4);
        // ordering differs between epochs (with overwhelming probability)
        let mut second_epoch = vec![b];
        for _ in 0..3 {
            second_epoch.push(s.next_indices());
        }
        assert_ne!(first_epoch, second_epoch);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Shard::new(2, 4, 100, 8, 11);
        let mut b = Shard::new(2, 4, 100, 8, 11);
        for _ in 0..10 {
            assert_eq!(a.next_indices(), b.next_indices());
        }
    }

    #[test]
    fn rekey_to_launch_values_is_a_noop() {
        let mut a = Shard::new(2, 4, 100, 8, 11);
        let mut b = Shard::new(2, 4, 100, 8, 11);
        a.next_indices();
        b.next_indices();
        a.rekey(2, 4, 0); // launch values: the static-fleet bypass
        for _ in 0..10 {
            assert_eq!(a.next_indices(), b.next_indices());
        }
    }

    #[test]
    fn rekeyed_ranks_partition_the_dataset() {
        // fleet of 4 shrinks to members {1, 3}: ranks 0 and 1 of 2
        let len = 57;
        let mut seen = HashSet::new();
        let mut total = 0;
        for (rank, w) in [(0usize, 1usize), (1, 3)] {
            let mut s = Shard::new(w, 4, len, 1, 9);
            s.rekey(rank, 2, 1);
            total += s.shard_len();
            for j in 0..s.shard_len() {
                assert!(seen.insert(rank + j * 2), "rank {rank} re-owns an index");
            }
        }
        assert_eq!(total, len);
        assert_eq!(seen.len(), len);
    }

    #[test]
    fn rekey_is_deterministic_and_epoch_keyed() {
        let mut a = Shard::new(1, 4, 80, 4, 5);
        let mut b = Shard::new(1, 4, 80, 4, 5);
        a.next_indices();
        b.next_indices();
        b.next_indices(); // replicas may be at different cursors
        a.rekey(0, 2, 3);
        b.rekey(0, 2, 3);
        for _ in 0..8 {
            assert_eq!(a.next_indices(), b.next_indices(), "identical (epoch, seed, member-set)");
        }
        // a different fleet epoch re-derives a different visit order
        let mut c = Shard::new(1, 4, 80, 4, 5);
        c.rekey(0, 2, 4);
        a.rekey(0, 2, 3); // no-op: same key
        assert_ne!(a.next_indices(), c.next_indices());
    }
}
