//! Worker data sharding.
//!
//! The paper partitions the training set into n equal parts (Sec. VI). We
//! use strided assignment: worker w of n owns indices {w, w+n, w+2n, ...},
//! and each epoch reshuffles the *visit order* of the shard deterministically
//! from (seed, epoch) — every worker sees only its shard, every sample is
//! visited once per epoch.

use crate::util::Pcg64;

#[derive(Clone, Debug)]
pub struct Shard {
    pub worker: usize,
    pub n_workers: usize,
    pub dataset_len: usize,
    pub batch: usize,
    seed: u64,
    epoch: u64,
    order: Vec<usize>,
    cursor: usize,
}

impl Shard {
    pub fn new(worker: usize, n_workers: usize, dataset_len: usize, batch: usize, seed: u64) -> Self {
        assert!(worker < n_workers, "worker {worker} >= n_workers {n_workers}");
        assert!(batch > 0);
        let mut s = Self {
            worker,
            n_workers,
            dataset_len,
            batch,
            seed,
            epoch: 0,
            order: Vec::new(),
            cursor: 0,
        };
        s.reshuffle();
        s
    }

    /// Samples owned by this worker.
    pub fn shard_len(&self) -> usize {
        let d = self.dataset_len;
        let (n, w) = (self.n_workers, self.worker);
        if d == 0 {
            0
        } else {
            (d - w + n - 1) / n
        }
    }

    /// Batches per epoch (floor — ragged tails are dropped like the usual
    /// drop_remainder=True input pipelines).
    pub fn batches_per_epoch(&self) -> usize {
        self.shard_len() / self.batch
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    fn reshuffle(&mut self) {
        self.order = (0..self.shard_len())
            .map(|j| self.worker + j * self.n_workers)
            .collect();
        let mut rng = Pcg64::new(self.seed ^ (self.epoch.wrapping_mul(0x9E37)), self.worker as u64);
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next batch of sample indices; rolls the epoch when exhausted.
    pub fn next_indices(&mut self) -> Vec<usize> {
        if self.cursor + self.batch > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let out = self.order[self.cursor..self.cursor + self.batch].to_vec();
        self.cursor += self.batch;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn shards_partition_dataset() {
        let n = 4;
        let len = 103;
        let mut seen = HashSet::new();
        let mut total = 0;
        for w in 0..n {
            let s = Shard::new(w, n, len, 1, 0);
            total += s.shard_len();
            for j in 0..s.shard_len() {
                assert!(seen.insert(w + j * n));
            }
        }
        assert_eq!(total, len);
        assert_eq!(seen.len(), len);
    }

    #[test]
    fn epoch_visits_each_sample_once() {
        let mut s = Shard::new(1, 3, 30, 2, 7);
        let mut seen = Vec::new();
        for _ in 0..s.batches_per_epoch() {
            seen.extend(s.next_indices());
        }
        seen.sort_unstable();
        let expect: Vec<usize> = (0..10).map(|j| 1 + 3 * j).collect();
        assert_eq!(seen, expect);
    }

    #[test]
    fn epoch_rolls_and_reshuffles() {
        let mut s = Shard::new(0, 1, 16, 4, 3);
        let mut first_epoch = Vec::new();
        for _ in 0..4 {
            first_epoch.push(s.next_indices());
        }
        assert_eq!(s.epoch(), 0);
        let b = s.next_indices(); // rolls into epoch 1
        assert_eq!(s.epoch(), 1);
        assert_eq!(b.len(), 4);
        // ordering differs between epochs (with overwhelming probability)
        let mut second_epoch = vec![b];
        for _ in 0..3 {
            second_epoch.push(s.next_indices());
        }
        assert_ne!(first_epoch, second_epoch);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Shard::new(2, 4, 100, 8, 11);
        let mut b = Shard::new(2, 4, 100, 8, 11);
        for _ in 0..10 {
            assert_eq!(a.next_indices(), b.next_indices());
        }
    }
}
