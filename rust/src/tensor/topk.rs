//! Top-K magnitude selection.
//!
//! Semantics must match `jax.lax.top_k(|u|)`: the K components of largest
//! magnitude win; ties break toward the **lower index**. Selection is the
//! dominant L3 cost for large d, so the implementation is an in-place
//! quickselect over (|value|, index) keys — O(d) average — followed by a
//! sort of only the selected K indices.

/// Returns the indices of the K largest-|.| components, in ascending index
/// order (the order the sparse payload encoder wants). Allocating wrapper
/// over [`select_topk_into`].
pub fn select_topk_indices(u: &[f32], k: usize) -> Vec<u32> {
    let mut out = Vec::new();
    select_topk_into(u, k, &mut out);
    out
}

/// Select into a caller-owned buffer — the zero-allocation hot path
/// (`out` is cleared first; candidate/sample scratch is thread-local, so
/// steady-state calls perform no heap allocation at all).
///
/// Hot path (K ≪ d): a sampled magnitude threshold prunes the candidate set
/// to ~1.5K before the exact quickselect — ~10× over the naive full-range
/// quickselect at d≈10⁵ (EXPERIMENTS.md §Perf). Falls back to the full
/// quickselect when the sample under-estimates the threshold, so the result
/// is always exact.
pub fn select_topk_into(u: &[f32], k: usize, out: &mut Vec<u32>) {
    out.clear();
    let d = u.len();
    if k == 0 || d == 0 {
        return;
    }
    if k >= d {
        out.extend(0..d as u32);
        return;
    }
    SCRATCH.with(|cell| {
        let scratch = &mut *cell.borrow_mut();
        if select_via_sampled_threshold(u, k, scratch, out) {
            return;
        }
        select_full(u, k, &mut scratch.idx, out);
    });
}

/// Reusable candidate-index and magnitude-sample buffers.
#[derive(Default)]
struct Scratch {
    idx: Vec<u32>,
    sample: Vec<f32>,
}

std::thread_local! {
    static SCRATCH: std::cell::RefCell<Scratch> = std::cell::RefCell::new(Scratch::default());
}

/// Exact selection over the full index range (always correct).
fn select_full(u: &[f32], k: usize, idx: &mut Vec<u32>, out: &mut Vec<u32>) {
    idx.clear();
    idx.extend(0..u.len() as u32);
    quickselect(idx, u, k - 1);
    out.extend_from_slice(&idx[..k]);
    out.sort_unstable();
}

/// Candidate-pruned selection. Returns false when the sampled threshold was
/// too aggressive (fewer than k candidates survive) — caller falls back.
fn select_via_sampled_threshold(
    u: &[f32],
    k: usize,
    scratch: &mut Scratch,
    out: &mut Vec<u32>,
) -> bool {
    let d = u.len();
    const SAMPLE: usize = 512;
    if d < 4 * SAMPLE || k * 8 >= d {
        return false; // pruning not worth it / sample too coarse
    }
    // deterministic strided sample of magnitudes, sorted descending
    let stride = d / SAMPLE;
    let sample = &mut scratch.sample;
    sample.clear();
    sample.extend((0..SAMPLE).map(|i| u[i * stride].abs()));
    sample.sort_unstable_by(|a, b| b.total_cmp(a));
    // threshold at ~1.5x the target quantile plus slack: low enough that
    // >= k candidates survive with high probability, high enough to prune
    let q = ((SAMPLE * k) / d) * 3 / 2 + 8;
    let t = sample[q.min(SAMPLE - 1)];
    let idx = &mut scratch.idx;
    idx.clear();
    for (i, &v) in u.iter().enumerate() {
        // total_cmp keeps NaN (ranked above all magnitudes by `better`)
        // inside the candidate set
        if v.abs().total_cmp(&t).is_ge() {
            idx.push(i as u32);
        }
    }
    if idx.len() < k {
        return false;
    }
    if idx.len() > k {
        quickselect(idx, u, k - 1);
    }
    out.extend_from_slice(&idx[..k]);
    out.sort_unstable();
    true
}

/// The |.| threshold that Top-K implies: |u[i]| of the K-th kept component.
/// Used by the threshold-reuse ablation (approximate Top-K).
pub fn topk_threshold(u: &[f32], k: usize) -> f32 {
    if k == 0 {
        return f32::INFINITY;
    }
    let idx = select_topk_indices(u, k);
    idx.iter().map(|&i| u[i as usize].abs()).fold(f32::INFINITY, f32::min)
}

#[inline]
fn better(u: &[f32], a: u32, b: u32) -> bool {
    // "a ranks before b": larger magnitude, ties to lower index. total_cmp
    // gives NaN a consistent rank (above +inf for |.|), so pathological
    // inputs (e.g. a diverged model) cannot degrade quickselect to O(d²)
    // through incoherent comparisons.
    let ma = u[a as usize].abs();
    let mb = u[b as usize].abs();
    match ma.total_cmp(&mb) {
        std::cmp::Ordering::Greater => true,
        std::cmp::Ordering::Less => false,
        std::cmp::Ordering::Equal => a < b,
    }
}

/// Partial quickselect: after return, idx[0..=kth] are the top (kth+1)
/// elements (unordered) under `better`.
fn quickselect(idx: &mut [u32], u: &[f32], kth: usize) {
    let (mut lo, mut hi) = (0usize, idx.len() - 1);
    // deterministic xorshift for pivot choice — keeps runs reproducible
    let mut rng_state: u64 = 0x243F6A8885A308D3;
    while lo < hi {
        rng_state ^= rng_state << 13;
        rng_state ^= rng_state >> 7;
        rng_state ^= rng_state << 17;
        let pivot_i = lo + (rng_state % (hi - lo + 1) as u64) as usize;
        let p = partition(idx, u, lo, hi, pivot_i);
        match p.cmp(&kth) {
            std::cmp::Ordering::Equal => return,
            std::cmp::Ordering::Less => lo = p + 1,
            std::cmp::Ordering::Greater => hi = p - 1,
        }
    }
}

/// Hoare-style partition around idx[pivot_i]; returns final pivot position.
fn partition(idx: &mut [u32], u: &[f32], lo: usize, hi: usize, pivot_i: usize) -> usize {
    idx.swap(pivot_i, hi);
    let pivot = idx[hi];
    let mut store = lo;
    for i in lo..hi {
        if better(u, idx[i], pivot) {
            idx.swap(i, store);
            store += 1;
        }
    }
    idx.swap(store, hi);
    store
}

/// Reference O(d log d) implementation used by tests and as a fallback.
pub fn select_topk_indices_sort(u: &[f32], k: usize) -> Vec<u32> {
    let d = u.len();
    if k == 0 || d == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..d as u32).collect();
    idx.sort_by(|&a, &b| {
        let ma = u[a as usize].abs();
        let mb = u[b as usize].abs();
        mb.total_cmp(&ma).then(a.cmp(&b))
    });
    let mut out: Vec<u32> = idx[..k.min(d)].to_vec();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn basic_selection() {
        let u = [0.1, -5.0, 2.0, -0.2, 3.0];
        assert_eq!(select_topk_indices(&u, 2), vec![1, 4]);
        assert_eq!(select_topk_indices(&u, 0), Vec::<u32>::new());
        assert_eq!(select_topk_indices(&u, 5), vec![0, 1, 2, 3, 4]);
        assert_eq!(select_topk_indices(&u, 99), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn tie_break_prefers_lower_index() {
        let u = [1.0, -1.0, 1.0, 1.0];
        assert_eq!(select_topk_indices(&u, 2), vec![0, 1]);
        assert_eq!(select_topk_indices(&u, 3), vec![0, 1, 2]);
    }

    #[test]
    fn matches_sort_reference_randomized() {
        let mut rng = Pcg64::seeded(7);
        for trial in 0..200 {
            let d = 1 + (rng.below(300) as usize);
            let k = rng.below(d as u64 + 1) as usize;
            let mut u = vec![0.0f32; d];
            for x in u.iter_mut() {
                // quantize values so magnitude ties actually occur
                *x = ((rng.gaussian() * 3.0).round() / 3.0) as f32;
            }
            let fast = select_topk_indices(&u, k);
            let slow = select_topk_indices_sort(&u, k);
            assert_eq!(fast, slow, "trial={trial} d={d} k={k}");
        }
    }

    #[test]
    fn sampled_threshold_path_matches_reference() {
        // d large enough to trigger select_via_sampled_threshold
        let mut rng = Pcg64::seeded(21);
        for trial in 0..10 {
            let d = 20_000 + (rng.below(5000) as usize);
            for k in [1usize, 5, 64, 500, d / 9] {
                let mut u = vec![0.0f32; d];
                for x in u.iter_mut() {
                    *x = ((rng.gaussian() * 4.0).round() / 4.0) as f32; // ties
                }
                let fast = select_topk_indices(&u, k);
                let slow = select_topk_indices_sort(&u, k);
                assert_eq!(fast, slow, "trial={trial} d={d} k={k}");
            }
        }
    }

    #[test]
    fn sampled_path_handles_nan_and_constant_vectors() {
        let mut u = vec![1.0f32; 30_000];
        u[17] = f32::NAN;
        let got = select_topk_indices(&u, 3);
        // NaN ranks highest under total_cmp(|.|); ties then lowest indices
        assert_eq!(got.len(), 3);
        assert!(got.contains(&17), "{got:?}");
        let flat = vec![2.5f32; 30_000];
        assert_eq!(select_topk_indices(&flat, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn threshold_is_kth_magnitude() {
        let u = [0.5, 3.0, -2.0, 1.0];
        assert_eq!(topk_threshold(&u, 2), 2.0);
        assert_eq!(topk_threshold(&u, 4), 0.5);
        assert_eq!(topk_threshold(&u, 0), f32::INFINITY);
    }

    #[test]
    fn all_zeros_keeps_lowest_indices() {
        let u = [0.0f32; 10];
        assert_eq!(select_topk_indices(&u, 3), vec![0, 1, 2]);
    }

    #[test]
    fn into_variant_matches_and_reuses_the_buffer() {
        let mut rng = Pcg64::seeded(33);
        let mut out = Vec::new();
        for trial in 0..20 {
            let d = if trial % 2 == 0 { 25_000 } else { 1 + rng.below(500) as usize };
            let k = 1 + rng.below(d as u64) as usize;
            let mut u = vec![0.0f32; d];
            rng.fill_gaussian(&mut u, 1.0);
            select_topk_into(&u, k, &mut out);
            assert_eq!(out, select_topk_indices(&u, k), "trial={trial} d={d} k={k}");
        }
        // cleared on every call, including the degenerate ones
        select_topk_into(&[1.0, 2.0], 0, &mut out);
        assert!(out.is_empty());
    }
}
