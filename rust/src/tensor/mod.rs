//! Flat f32 vector kernels for the coordinator hot path.
//!
//! Everything the master/worker loop does outside PJRT is expressed over
//! contiguous `&[f32]` slices of dimension d: axpy-style updates, norms, and
//! the Top-K magnitude selection (quickselect — the L3 hot spot for large d).

pub mod topk;

pub use topk::{select_topk_indices, select_topk_into, topk_threshold};

/// y += a * x
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// y = a * x + b * y (in place on y)
pub fn axpby(a: f32, x: &[f32], b: f32, y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = a * xi + b * *yi;
    }
}

/// out = x - y
pub fn sub_into(x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for ((o, xi), yi) in out.iter_mut().zip(x).zip(y) {
        *o = xi - yi;
    }
}

/// out = x + y
pub fn add_into(x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for ((o, xi), yi) in out.iter_mut().zip(x).zip(y) {
        *o = xi + yi;
    }
}

/// x *= a
pub fn scale(x: &mut [f32], a: f32) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

pub fn fill(x: &mut [f32], v: f32) {
    for xi in x.iter_mut() {
        *xi = v;
    }
}

/// Squared l2 norm, accumulated in f64 for stability.
pub fn norm2_sq(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

pub fn norm2(x: &[f32]) -> f64 {
    norm2_sq(x).sqrt()
}

/// Mean of |x| (the Scaled-sign scale), f64 accumulator.
pub fn mean_abs(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    let s: f64 = x.iter().map(|&v| v.abs() as f64).sum();
    (s / x.len() as f64) as f32
}

pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| a as f64 * b as f64).sum()
}

/// Count of non-zero components (payload size driver for sparse schemes).
pub fn nnz(x: &[f32]) -> usize {
    x.iter().filter(|&&v| v != 0.0).count()
}

/// Mean squared difference (1/d)||x-y||^2 — the Fig. 8 right-panel metric.
pub fn mse(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    if x.is_empty() {
        return 0.0;
    }
    let s: f64 = x
        .iter()
        .zip(y)
        .map(|(&a, &b)| {
            let d = a as f64 - b as f64;
            d * d
        })
        .sum();
    s / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpby_momentum_form() {
        // v = (1-beta) g + beta v — the Eq. (1a) update as axpby
        let g = [1.0f32, -1.0];
        let mut v = [0.5f32, 0.5];
        axpby(0.1, &g, 0.9, &mut v);
        assert!((v[0] - 0.55).abs() < 1e-7);
        assert!((v[1] - 0.35).abs() < 1e-7);
    }

    #[test]
    fn norms_and_mse() {
        let x = [3.0f32, 4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-12);
        let y = [0.0f32, 0.0];
        assert!((mse(&x, &y) - 12.5).abs() < 1e-12);
        assert_eq!(mse(&[], &[]), 0.0);
    }

    #[test]
    fn mean_abs_and_nnz() {
        let x = [-2.0f32, 0.0, 2.0, 4.0];
        assert!((mean_abs(&x) - 2.0).abs() < 1e-7);
        assert_eq!(nnz(&x), 3);
        assert_eq!(mean_abs(&[]), 0.0);
    }

    #[test]
    fn add_sub_roundtrip() {
        let x = [1.0f32, 2.0, 3.0];
        let y = [0.5f32, -0.5, 1.0];
        let mut s = [0.0f32; 3];
        let mut back = [0.0f32; 3];
        add_into(&x, &y, &mut s);
        sub_into(&s, &y, &mut back);
        assert_eq!(back, x);
    }
}
