//! Shared-seed Bernoulli Rand-K selection.
//!
//! Mask derivation must match `python/compile/kernels/ref.py::randk_hash`
//! exactly: the worker and master derive the same mask from (round, prob)
//! so the indices never travel on the wire.

const H1: u32 = 0x9E37_79B1;
const H2: u32 = 0x85EB_CA6B;
const M1: u32 = 0x7FEB_352D;
const M2: u32 = 0x846C_A68B;

/// triple32-style mix of (component index, round seed).
#[inline]
pub fn hash32(j: u32, seed: u32) -> u32 {
    let mut key = (j.wrapping_add(1))
        .wrapping_mul(H1)
        .wrapping_add(seed.wrapping_mul(H2));
    key ^= key >> 16;
    key = key.wrapping_mul(M1);
    key ^= key >> 15;
    key = key.wrapping_mul(M2);
    key ^= key >> 16;
    key
}

#[inline]
pub fn keep_threshold(prob: f32) -> u32 {
    let t = (prob as f64 * 4294967296.0).floor();
    t.clamp(0.0, 4294967295.0) as u32
}

/// Should component j be kept in round `seed`?
#[inline]
pub fn keep(j: u32, seed: u32, thresh: u32) -> bool {
    hash32(j, seed) < thresh
}

/// All kept indices for a round, ascending.
pub fn mask_indices(d: usize, round: u64, prob: f32) -> Vec<u32> {
    let mut out = Vec::new();
    mask_indices_into(d, round, prob, &mut out);
    out
}

/// [`mask_indices`] into a caller-owned buffer (cleared first) — the
/// zero-allocation path for the reusable encode/decode scratch.
pub fn mask_indices_into(d: usize, round: u64, prob: f32, out: &mut Vec<u32>) {
    out.clear();
    let seed = round as u32;
    let thresh = keep_threshold(prob);
    out.extend((0..d as u32).filter(|&j| keep(j, seed, thresh)));
}

/// Apply the mask: out[j] = u[j] if kept else 0.
pub fn apply(u: &[f32], out: &mut [f32], round: u64, prob: f32) {
    debug_assert_eq!(u.len(), out.len());
    let seed = round as u32;
    let thresh = keep_threshold(prob);
    for (j, (o, &v)) in out.iter_mut().zip(u).enumerate() {
        *o = if keep(j as u32, seed, thresh) { v } else { 0.0 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = mask_indices(10_000, 7, 0.05);
        let b = mask_indices(10_000, 7, 0.05);
        let c = mask_indices(10_000, 8, 0.05);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn density_close_to_prob() {
        let d = 100_000;
        for &p in &[0.01f32, 0.1, 0.5] {
            let n = mask_indices(d, 3, p).len() as f64;
            let expect = d as f64 * p as f64;
            assert!((n - expect).abs() < 4.0 * (expect).sqrt() + 10.0, "p={p} n={n}");
        }
    }

    #[test]
    fn edge_probs() {
        assert!(mask_indices(1000, 0, 0.0).is_empty());
        assert_eq!(mask_indices(1000, 0, 1.0).len(), 1000);
    }

    #[test]
    fn apply_matches_mask() {
        let d = 500;
        let u: Vec<f32> = (0..d).map(|i| i as f32 + 1.0).collect();
        let mut out = vec![0.0f32; d];
        apply(&u, &mut out, 11, 0.2);
        let idx = mask_indices(d, 11, 0.2);
        for j in 0..d {
            if idx.contains(&(j as u32)) {
                assert_eq!(out[j], u[j]);
            } else {
                assert_eq!(out[j], 0.0);
            }
        }
    }

    #[test]
    fn hash_reference_values_stable() {
        // pin the hash so the python side can't silently diverge
        // (mirrored in python/tests via the mask equality tests)
        assert_eq!(hash32(0, 0), hash32(0, 0));
        assert_ne!(hash32(0, 0), hash32(1, 0));
        assert_ne!(hash32(0, 0), hash32(0, 1));
    }
}
