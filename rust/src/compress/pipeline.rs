//! The full Fig.-2 worker pipeline (paper Eq. (1)) and the master-side
//! decode-and-predict chain — pure-Rust backend, built from the trait
//! objects of [`crate::scheme`] (any `Quantize` × any `Predict`).
//!
//! Per iteration t at worker i:
//! ```text
//! v_t = β v_{t-1} + (1-β) g_t              (1a) momentum
//! r_t = v_t + (η_{t-1}/η_t) e_{t-1}        (1b) error-feedback (if EF)
//! u_t = r_t − r̂_t                          (1c) prediction error
//! ũ_t = Q(u_t)                             (1d) quantizer
//! e_t = u_t − ũ_t                          (1e) quantization error
//! r̃_t = ũ_t + r̂_t                          (1f) reconstruction
//! r̂_{t+1} = P(r̃_t)                         (1g) predictor
//! ```
//! Note e_t is tracked even when EF is off — it is the Fig. 5 / Fig. 8
//! metric ‖e_t‖².

use std::sync::Arc;

use crate::coding::PayloadKind;
use crate::scheme::{Predict, Quantize, RoundScratch};

use super::{Predictor, SchemeCfg};

/// Per-step diagnostics (the quantities the paper plots).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// ‖e_t‖² — quantization error energy (Fig. 5).
    pub e_norm_sq: f64,
    /// (1/d)‖e_t‖² — the Fig. 8 right-panel metric.
    pub e_mse: f64,
    /// ‖u_t‖² — quantizer input energy (prediction shrinks this).
    pub u_norm_sq: f64,
    /// non-zeros in ũ_t (payload size driver).
    pub nnz: usize,
}

/// Worker-side state + scratch for one model replica.
#[derive(Clone, Debug)]
pub struct WorkerPipeline {
    quantizer: Arc<dyn Quantize>,
    predictor: Box<dyn Predict>,
    ef: bool,
    beta: f32,
    d: usize,
    round: u64,
    v: Vec<f32>,
    e: Vec<f32>,
    u: Vec<f32>,
    utilde: Vec<f32>,
    /// reusable buffer arena (quantizer support etc.) — steady-state rounds
    /// allocate nothing
    scratch: RoundScratch,
    /// whether `scratch.indices` holds the last step's ũ support
    sparse_valid: bool,
}

impl WorkerPipeline {
    /// Build from the legacy closed-enum configuration (shim path — maps
    /// onto the same trait objects as the registry).
    pub fn new(cfg: SchemeCfg, d: usize) -> Self {
        let predictor = Predictor::new(cfg.predictor, cfg.beta, d).into_box();
        Self::from_parts(cfg.quantizer.to_object(), predictor, cfg.ef, cfg.beta, d)
    }

    /// Build from trait objects (the Scheme-API path).
    pub fn from_parts(
        quantizer: Arc<dyn Quantize>,
        predictor: Box<dyn Predict>,
        ef: bool,
        beta: f32,
        d: usize,
    ) -> Self {
        debug_assert_eq!(predictor.dim(), d, "predictor dim mismatch");
        Self {
            quantizer,
            predictor,
            ef,
            beta,
            d,
            round: 0,
            v: vec![0.0; d],
            e: vec![0.0; d],
            u: vec![0.0; d],
            utilde: vec![0.0; d],
            scratch: RoundScratch::default(),
            sparse_valid: false,
        }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn round(&self) -> u64 {
        self.round
    }

    pub fn ef(&self) -> bool {
        self.ef
    }

    pub fn beta(&self) -> f32 {
        self.beta
    }

    pub fn quantizer(&self) -> &dyn Quantize {
        &*self.quantizer
    }

    pub fn predictor(&self) -> &dyn Predict {
        &*self.predictor
    }

    /// Wire format of this pipeline's quantizer.
    pub fn payload_kind(&self) -> PayloadKind {
        self.quantizer.payload_kind()
    }

    /// Momentum vector v_t (read-only; Fig. 6 traces).
    pub fn momentum(&self) -> &[f32] {
        &self.v
    }

    /// Quantization error e_t.
    pub fn error(&self) -> &[f32] {
        &self.e
    }

    /// Quantizer input u_t of the last step.
    pub fn quantizer_input(&self) -> &[f32] {
        &self.u
    }

    /// Quantized update ũ_t of the last step — what gets encoded.
    pub fn utilde(&self) -> &[f32] {
        &self.utilde
    }

    /// Current prediction r̂_t (before the next step consumes it).
    pub fn rhat(&self) -> &[f32] {
        self.predictor.rhat()
    }

    /// Support indices of the last step's ũ_t (ascending), when the
    /// quantizer reported them — the exact-sparse encode fast path.
    pub fn sparse_support(&self) -> Option<&[u32]> {
        if self.sparse_valid {
            Some(&self.scratch.indices)
        } else {
            None
        }
    }

    /// Run one full Eq. (1) iteration. `lr_ratio` = η_{t-1}/η_t (0 at t=0).
    pub fn step(&mut self, g: &[f32], lr_ratio: f32) -> StepStats {
        assert_eq!(g.len(), self.d, "gradient dim mismatch");
        let beta = self.beta;
        let one_minus = 1.0 - beta;
        let rhat = self.predictor.rhat();

        // (1a)-(1c) fused: v, r, u in one pass (mirrors the Pallas kernel),
        // with the EF branch hoisted out of the element loop so the f32
        // work auto-vectorizes. The f64 norm accumulation keeps its
        // sequential order — StepStats are bit-pinned by the golden tests.
        let mut u_norm_sq = 0.0f64;
        if self.ef {
            for i in 0..self.d {
                let v = beta * self.v[i] + one_minus * g[i];
                self.v[i] = v;
                let u = v + lr_ratio * self.e[i] - rhat[i];
                self.u[i] = u;
                u_norm_sq += (u as f64) * (u as f64);
            }
        } else {
            for i in 0..self.d {
                let v = beta * self.v[i] + one_minus * g[i];
                self.v[i] = v;
                let u = v - rhat[i];
                self.u[i] = u;
                u_norm_sq += (u as f64) * (u as f64);
            }
        }

        // (1d) — exact-sparse quantizers also report their support into the
        // reusable scratch, which the encoder consumes (O(K) instead of an
        // O(d) re-scan) and which costs zero allocation in steady state
        self.sparse_valid = self.quantizer.quantize_sparse(
            &self.u,
            &mut self.utilde,
            self.round,
            &mut self.scratch.indices,
        );

        // (1e) + stats
        let mut e_norm_sq = 0.0f64;
        let mut nnz = 0usize;
        for i in 0..self.d {
            let e = self.u[i] - self.utilde[i];
            self.e[i] = e;
            e_norm_sq += (e as f64) * (e as f64);
            nnz += (self.utilde[i] != 0.0) as usize;
        }

        // (1f)+(1g): predictor consumes ũ_t (r̃ = ũ + r̂ internally).
        self.predictor.update(&self.utilde);

        self.round += 1;
        StepStats {
            e_norm_sq,
            e_mse: e_norm_sq / self.d as f64,
            u_norm_sq,
            nnz,
        }
    }

    /// HLO-backend bridge: replace all Eq.-(1) state with the outputs of the
    /// AOT compress artifact for this step (see `runtime::CompressExec`).
    pub fn overwrite_state_from_artifact(
        &mut self,
        utilde: &[f32],
        v: &[f32],
        e: &[f32],
        rhat: &[f32],
        p: Option<&[f32]>,
        s: Option<&[f32]>,
        tau: Option<&[f32]>,
    ) {
        self.utilde.copy_from_slice(utilde);
        self.v.copy_from_slice(v);
        self.e.copy_from_slice(e);
        // reconstruct the quantizer input via Eq. (1e): u = ũ + e
        for i in 0..self.d {
            self.u[i] = utilde[i] + e[i];
        }
        self.predictor.load_state(rhat, p, s, tau);
        // the artifact hands back dense state only — no support list
        self.sparse_valid = false;
        self.round += 1;
    }

    /// State vectors handed to the HLO compress artifact
    /// (g is supplied by the caller): (v, e, r̂, p, S, τ).
    pub fn hlo_inputs(&self) -> (&[f32], &[f32], &[f32], Option<&[f32]>, Option<&[f32]>, Option<&[f32]>) {
        let st = self.predictor.state_view();
        (&self.v, &self.e, st.rhat, st.p, st.s, st.tau)
    }
}

/// Master-side per-worker chain: decode ũ → r̃ = ũ + r̂ → advance P.
#[derive(Clone, Debug)]
pub struct MasterChain {
    predictor: Box<dyn Predict>,
    d: usize,
}

impl MasterChain {
    /// Legacy shim constructor (closed-enum configuration).
    pub fn new(cfg: &SchemeCfg, d: usize) -> Self {
        Self::from_predictor(Predictor::new(cfg.predictor, cfg.beta, d).into_box(), d)
    }

    /// Build from a trait object (the Scheme-API path).
    pub fn from_predictor(predictor: Box<dyn Predict>, d: usize) -> Self {
        debug_assert_eq!(predictor.dim(), d, "predictor dim mismatch");
        Self { predictor, d }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Consume a decoded ũ_t; write r̃_t into `rtilde_out`.
    pub fn receive(&mut self, utilde: &[f32], rtilde_out: &mut [f32]) {
        assert_eq!(utilde.len(), self.d);
        assert_eq!(rtilde_out.len(), self.d);
        // fused r̃ = ũ + r̂ + predictor advance: one pass instead of two,
        // bit-identical by the `Predict::update_into` contract
        self.predictor.update_into(utilde, rtilde_out);
    }

    pub fn rhat(&self) -> &[f32] {
        self.predictor.rhat()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{PredictorKind, QuantizerKind};
    use crate::util::Pcg64;

    fn gvec(rng: &mut Pcg64, d: usize) -> Vec<f32> {
        let mut g = vec![0.0f32; d];
        rng.fill_gaussian(&mut g, 1.0);
        g
    }

    #[test]
    fn baseline_is_exact_momentum() {
        // Q=none, P=zero, no EF: utilde == v and e == 0
        let d = 128;
        let cfg = SchemeCfg::baseline(0.9);
        let mut pipe = WorkerPipeline::new(cfg, d);
        let mut rng = Pcg64::seeded(1);
        let mut v_ref = vec![0.0f32; d];
        for _ in 0..20 {
            let g = gvec(&mut rng, d);
            let stats = pipe.step(&g, 1.0);
            let one_minus = 1.0f32 - 0.9f32; // match the pipeline's exact fp
            for i in 0..d {
                v_ref[i] = 0.9 * v_ref[i] + one_minus * g[i];
            }
            assert_eq!(pipe.utilde(), &v_ref[..]);
            assert_eq!(stats.e_norm_sq, 0.0);
            assert_eq!(stats.nnz, d);
        }
    }

    #[test]
    fn master_chain_reconstruction_identity() {
        // r_t − r̃_t = e_t (paper Eq. (8)): master's r̃ equals worker's u+r̂−e
        let d = 256;
        let cfg = SchemeCfg::new(
            QuantizerKind::TopK { k: 16 },
            PredictorKind::EstK,
            true,
            0.95,
        )
        .unwrap();
        let mut worker = WorkerPipeline::new(cfg.clone(), d);
        let mut master = MasterChain::new(&cfg, d);
        let mut rng = Pcg64::seeded(2);
        let mut rtilde = vec![0.0f32; d];
        for t in 0..100 {
            let g = gvec(&mut rng, d);
            let lr_ratio = if t == 0 { 0.0 } else { 1.0 };
            // capture r̂_t and v/e BEFORE the step advances the predictor
            let rhat_before: Vec<f32> = worker.rhat().to_vec();
            worker.step(&g, lr_ratio);
            master.receive(worker.utilde(), &mut rtilde);
            // master r̂ stays in bit-exact sync with worker r̂
            assert_eq!(master.rhat(), worker.rhat(), "t={t}");
            // r̃ = ũ + r̂(pre-update)
            for i in 0..d {
                let want = worker.utilde()[i] + rhat_before[i];
                assert_eq!(rtilde[i], want);
            }
            // e_t = u_t − ũ_t by construction
            for i in 0..d {
                let e = worker.quantizer_input()[i] - worker.utilde()[i];
                assert_eq!(worker.error()[i], e);
            }
        }
    }

    #[test]
    fn prediction_reduces_quantizer_input_variance() {
        // the paper's core claim (Sec. III-A): with temporally-correlated
        // streams, P_Lin shrinks var(u) vs no prediction
        let d = 2048;
        let beta = 0.99f32;
        let mk = |pred| {
            SchemeCfg::new(QuantizerKind::Sign, pred, false, beta).unwrap()
        };
        let mut with_p = WorkerPipeline::new(mk(PredictorKind::PLin), d);
        let mut without_p = WorkerPipeline::new(mk(PredictorKind::Zero), d);
        let mut rng = Pcg64::seeded(3);
        // correlated gradient stream: g_t = base + noise
        let base = gvec(&mut rng, d);
        let (mut uw, mut uo) = (0.0, 0.0);
        for t in 0..300 {
            let mut g = base.clone();
            for x in g.iter_mut() {
                *x += 0.3 * rng.gaussian() as f32;
            }
            let sw = with_p.step(&g, 1.0);
            let so = without_p.step(&g, 1.0);
            if t >= 100 {
                uw += sw.u_norm_sq;
                uo += so.u_norm_sq;
            }
        }
        assert!(
            uw < uo * 0.25,
            "prediction should shrink ||u||^2 by ~(1-beta) factors: {uw} vs {uo}"
        );
    }

    #[test]
    fn plin_with_ef_error_grows() {
        // paper Fig. 5: P_Lin + EF => ||e_t||^2 grows; without EF it stays flat
        let d = 512;
        let mk = |ef| {
            SchemeCfg::new(
                QuantizerKind::TopKQ { k: 25 },
                PredictorKind::PLin,
                ef,
                0.99,
            )
            .unwrap()
        };
        let mut with_ef = WorkerPipeline::new(mk(true), d);
        let mut without_ef = WorkerPipeline::new(mk(false), d);
        let mut rng = Pcg64::seeded(4);
        let (mut e_ef_early, mut e_ef_late) = (0.0, 0.0);
        let (mut e_no_early, mut e_no_late) = (0.0, 0.0);
        for t in 0..120 {
            let g = gvec(&mut rng, d);
            let s1 = with_ef.step(&g, if t == 0 { 0.0 } else { 1.0 });
            let s2 = without_ef.step(&g, 0.0);
            if (10..30).contains(&t) {
                e_ef_early += s1.e_norm_sq;
                e_no_early += s2.e_norm_sq;
            }
            if t >= 100 {
                e_ef_late += s1.e_norm_sq;
                e_no_late += s2.e_norm_sq;
            }
        }
        assert!(e_ef_late > 5.0 * e_ef_early, "EF+PLin must diverge: {e_ef_early} -> {e_ef_late}");
        assert!(e_no_late < 3.0 * e_no_early, "no-EF stays bounded: {e_no_early} -> {e_no_late}");
    }

    #[test]
    fn estk_tracks_momentum_better_than_no_prediction() {
        // Fig. 6(c): with Est-K, max|u| over a stable stretch is roughly
        // halved vs Top-K without prediction
        let d = 1000;
        let k = 10;
        let beta = 0.995f32;
        let cfg_estk =
            SchemeCfg::new(QuantizerKind::TopK { k }, PredictorKind::EstK, true, beta).unwrap();
        let cfg_plain =
            SchemeCfg::new(QuantizerKind::TopK { k }, PredictorKind::Zero, true, beta).unwrap();
        let mut pe = WorkerPipeline::new(cfg_estk, d);
        let mut pp = WorkerPipeline::new(cfg_plain, d);
        let mut r1 = Pcg64::seeded(5);
        let mut r2 = Pcg64::seeded(5);
        let (mut umax_e, mut umax_p) = (0.0f32, 0.0f32);
        for t in 0..600 {
            let g1 = gvec(&mut r1, d);
            let g2 = gvec(&mut r2, d);
            assert_eq!(g1, g2);
            let lr = if t == 0 { 0.0 } else { 1.0 };
            pe.step(&g1, lr);
            pp.step(&g2, lr);
            if t >= 300 {
                umax_e = umax_e.max(pe.quantizer_input()[0].abs());
                umax_p = umax_p.max(pp.quantizer_input()[0].abs());
            }
        }
        assert!(
            umax_e < 0.8 * umax_p,
            "Est-K should shrink |u| vs plain Top-K: {umax_e} vs {umax_p}"
        );
    }

    #[test]
    fn lr_ratio_scales_fed_back_error() {
        let d = 8;
        let cfg = SchemeCfg::new(
            QuantizerKind::TopK { k: 1 },
            PredictorKind::Zero,
            true,
            0.0, // no momentum: v = g
        )
        .unwrap();
        let mut pipe = WorkerPipeline::new(cfg, d);
        let g = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        pipe.step(&g, 0.0); // t=0: keeps 8.0, e = [1..7, 0]
        let e0: Vec<f32> = pipe.error().to_vec();
        assert_eq!(e0[7], 0.0);
        // t=1 with lr_ratio=2: u = g + 2*e0
        pipe.step(&g, 2.0);
        for i in 0..d {
            let want = g[i] + 2.0 * e0[i];
            assert_eq!(pipe.quantizer_input()[i], want);
        }
    }

    #[test]
    fn from_parts_equals_enum_construction() {
        // the two construction paths must produce bit-identical pipelines
        let d = 200;
        let cfg = SchemeCfg::new(
            QuantizerKind::TopK { k: 9 },
            PredictorKind::EstK,
            true,
            0.95,
        )
        .unwrap();
        let mut a = WorkerPipeline::new(cfg.clone(), d);
        let mut b = WorkerPipeline::from_parts(
            cfg.quantizer.to_object(),
            Predictor::new(cfg.predictor, cfg.beta, d).into_box(),
            cfg.ef,
            cfg.beta,
            d,
        );
        let mut rng = Pcg64::seeded(12);
        for t in 0..50 {
            let g = gvec(&mut rng, d);
            let lr = if t == 0 { 0.0 } else { 1.0 };
            let sa = a.step(&g, lr);
            let sb = b.step(&g, lr);
            assert_eq!(sa.e_norm_sq, sb.e_norm_sq);
            assert_eq!(a.utilde(), b.utilde());
        }
        assert_eq!(a.quantizer().name(), "topk");
        assert_eq!(a.predictor().name(), "estk");
        assert!(a.ef());
    }
}
