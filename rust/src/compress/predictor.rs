//! Predictors P (paper Eq. (1g)) with their per-component state.
//!
//! The same `Predictor` value runs at the worker and (one per worker) at
//! the master, fed the identical decoded `utilde` stream — so the two
//! copies stay in bit-exact sync (same f32 ops in the same order).

use super::PredictorKind;

/// Predictor state machine. `rhat()` is the prediction of r_t used when the
/// current iteration's u_t = r_t − r̂_t is formed; `update(utilde)` advances
/// to r̂_{t+1} after the quantized update is known (Eq. (1g)).
#[derive(Clone, Debug)]
pub enum Predictor {
    Zero {
        zeros: Vec<f32>,
    },
    PLin {
        beta: f32,
        rhat: Vec<f32>,
    },
    EstK {
        beta: f32,
        rhat: Vec<f32>,
        /// last estimate of the momentum (time-average between peaks)
        p: Vec<f32>,
        /// sum of predictions issued since the last received update
        s: Vec<f32>,
        /// iterations since the last received update
        tau: Vec<f32>,
    },
}

impl Predictor {
    pub fn new(kind: PredictorKind, beta: f32, d: usize) -> Self {
        match kind {
            PredictorKind::Zero => Predictor::Zero { zeros: vec![0.0; d] },
            PredictorKind::PLin => Predictor::PLin { beta, rhat: vec![0.0; d] },
            PredictorKind::EstK => Predictor::EstK {
                beta,
                rhat: vec![0.0; d],
                p: vec![0.0; d],
                s: vec![0.0; d],
                tau: vec![0.0; d],
            },
        }
    }

    pub fn kind(&self) -> PredictorKind {
        match self {
            Predictor::Zero { .. } => PredictorKind::Zero,
            Predictor::PLin { .. } => PredictorKind::PLin,
            Predictor::EstK { .. } => PredictorKind::EstK,
        }
    }

    pub fn dim(&self) -> usize {
        self.rhat().len()
    }

    /// Current prediction r̂_t.
    pub fn rhat(&self) -> &[f32] {
        match self {
            Predictor::Zero { zeros } => zeros,
            Predictor::PLin { rhat, .. } => rhat,
            Predictor::EstK { rhat, .. } => rhat,
        }
    }

    /// Advance the state given the received quantized update ũ_t.
    pub fn update(&mut self, utilde: &[f32]) {
        match self {
            Predictor::Zero { .. } => {}
            Predictor::PLin { beta, rhat } => {
                // r̂_{t+1} = β·r̃_t = β·(ũ_t + r̂_t)
                debug_assert_eq!(rhat.len(), utilde.len());
                let b = *beta;
                for (r, &ut) in rhat.iter_mut().zip(utilde) {
                    *r = b * (ut + *r);
                }
            }
            Predictor::EstK { beta, rhat, p, s, tau } => {
                debug_assert_eq!(rhat.len(), utilde.len());
                let b = *beta;
                for i in 0..utilde.len() {
                    let ut = utilde[i];
                    if ut != 0.0 {
                        // received a Top-K peak: refresh the momentum
                        // estimate to the time-average since the last peak
                        let p_new = (s[i] + ut) / (tau[i] + 1.0);
                        let rh = b * p_new;
                        p[i] = p_new;
                        rhat[i] = rh;
                        s[i] = rh;
                        tau[i] = 0.0;
                    } else {
                        // miss: decay the chain, accumulate the prediction
                        let rh = b * rhat[i];
                        rhat[i] = rh;
                        s[i] += rh;
                        tau[i] += 1.0;
                    }
                }
            }
        }
    }

    /// Direct state access for the HLO-backend bridge (runtime feeds the
    /// artifact the same (r̂, p, S, τ) buffers it maintains here).
    pub fn state_view(&self) -> PredictorState<'_> {
        match self {
            Predictor::Zero { zeros } => PredictorState {
                rhat: zeros,
                p: None,
                s: None,
                tau: None,
            },
            Predictor::PLin { rhat, .. } => PredictorState { rhat, p: None, s: None, tau: None },
            Predictor::EstK { rhat, p, s, tau, .. } => PredictorState {
                rhat,
                p: Some(p),
                s: Some(s),
                tau: Some(tau),
            },
        }
    }

    /// Overwrite state from the HLO artifact outputs.
    pub fn load_state(&mut self, rhat_new: &[f32], p_new: Option<&[f32]>, s_new: Option<&[f32]>, tau_new: Option<&[f32]>) {
        match self {
            Predictor::Zero { .. } => {}
            Predictor::PLin { rhat, .. } => rhat.copy_from_slice(rhat_new),
            Predictor::EstK { rhat, p, s, tau, .. } => {
                rhat.copy_from_slice(rhat_new);
                if let Some(x) = p_new {
                    p.copy_from_slice(x);
                }
                if let Some(x) = s_new {
                    s.copy_from_slice(x);
                }
                if let Some(x) = tau_new {
                    tau.copy_from_slice(x);
                }
            }
        }
    }
}

/// Borrowed view of predictor state vectors.
pub struct PredictorState<'a> {
    pub rhat: &'a [f32],
    pub p: Option<&'a [f32]>,
    pub s: Option<&'a [f32]>,
    pub tau: Option<&'a [f32]>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_never_predicts() {
        let mut p = Predictor::new(PredictorKind::Zero, 0.9, 4);
        p.update(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.rhat(), &[0.0; 4]);
    }

    #[test]
    fn plin_geometric_chain() {
        let mut p = Predictor::new(PredictorKind::PLin, 0.5, 2);
        p.update(&[2.0, 0.0]); // rhat = 0.5*(2+0) = 1
        assert_eq!(p.rhat(), &[1.0, 0.0]);
        p.update(&[0.0, 0.0]); // rhat = 0.5*(0+1) = 0.5
        assert_eq!(p.rhat(), &[0.5, 0.0]);
    }

    #[test]
    fn estk_replays_paper_table3() {
        // the Table III trace (see python/tests/test_estk_table3.py)
        let beta = 0.9f32;
        let mut pr = Predictor::new(PredictorKind::EstK, beta, 1);
        let (u3, u6) = (2.5f32, -1.3f32);
        let stream = [0.0, 0.0, 0.0, u3, 0.0, 0.0, u6, 0.0];
        let mut rhats = Vec::new();
        let mut taus = Vec::new();
        for &ut in &stream {
            pr.update(&[ut]);
            rhats.push(pr.rhat()[0]);
            if let Predictor::EstK { tau, .. } = &pr {
                taus.push(tau[0]);
            }
        }
        let p3 = u3 / 4.0;
        assert!((rhats[3] - beta * p3).abs() < 1e-6);
        assert!((rhats[4] - beta * beta * p3).abs() < 1e-6);
        assert!((rhats[5] - beta.powi(3) * p3).abs() < 1e-6);
        let s6 = (beta + beta * beta + beta.powi(3)) * p3;
        let p6 = (s6 + u6) / 3.0;
        assert!((rhats[6] - beta * p6).abs() < 1e-5);
        assert_eq!(taus, vec![1.0, 2.0, 3.0, 0.0, 1.0, 2.0, 0.0, 1.0]);
    }

    #[test]
    fn worker_master_sync_bit_exact() {
        // both sides fed the same utilde stream -> identical rhat forever
        let mut rng = crate::util::Pcg64::seeded(8);
        for kind in [PredictorKind::PLin, PredictorKind::EstK] {
            let d = 64;
            let mut a = Predictor::new(kind, 0.97, d);
            let mut b = Predictor::new(kind, 0.97, d);
            for _ in 0..200 {
                let ut: Vec<f32> = (0..d)
                    .map(|_| {
                        if rng.uniform() < 0.1 {
                            rng.gaussian() as f32
                        } else {
                            0.0
                        }
                    })
                    .collect();
                a.update(&ut);
                b.update(&ut);
                assert_eq!(a.rhat(), b.rhat());
            }
        }
    }

    #[test]
    fn load_state_roundtrip() {
        let mut p = Predictor::new(PredictorKind::EstK, 0.9, 3);
        p.update(&[1.0, 0.0, -1.0]);
        let rh: Vec<f32> = p.rhat().to_vec();
        let (pp, ss, tt) = match &p {
            Predictor::EstK { p, s, tau, .. } => (p.clone(), s.clone(), tau.clone()),
            _ => unreachable!(),
        };
        let mut q = Predictor::new(PredictorKind::EstK, 0.9, 3);
        q.load_state(&rh, Some(&pp), Some(&ss), Some(&tt));
        assert_eq!(q.rhat(), p.rhat());
    }
}
