//! Legacy predictor selector — now a thin shim over the trait-based state
//! machines in [`crate::scheme::predict`].
//!
//! The numeric bodies (and their per-component state) live in
//! `ZeroPredictor` / `PLinPredictor` / `EstKPredictor`; this enum wraps one
//! of them so existing call sites keep compiling. New code should hold a
//! `Box<dyn Predict>` (what `WorkerPipeline` does internally).

use super::PredictorKind;
use crate::scheme::predict::{EstKPredictor, PLinPredictor, Predict, ZeroPredictor};

pub use crate::scheme::predict::PredictorState;

/// Predictor state machine (deprecated shim; see module docs). `rhat()` is
/// the prediction of r_t used when u_t = r_t − r̂_t is formed;
/// `update(utilde)` advances to r̂_{t+1} (Eq. (1g)).
#[derive(Clone, Debug)]
pub enum Predictor {
    Zero(ZeroPredictor),
    PLin(PLinPredictor),
    EstK(EstKPredictor),
}

impl Predictor {
    pub fn new(kind: PredictorKind, beta: f32, d: usize) -> Self {
        match kind {
            PredictorKind::Zero => Predictor::Zero(ZeroPredictor::new(d)),
            PredictorKind::PLin => Predictor::PLin(PLinPredictor::new(beta, d)),
            PredictorKind::EstK => Predictor::EstK(EstKPredictor::new(beta, d)),
        }
    }

    pub fn kind(&self) -> PredictorKind {
        match self {
            Predictor::Zero(_) => PredictorKind::Zero,
            Predictor::PLin(_) => PredictorKind::PLin,
            Predictor::EstK(_) => PredictorKind::EstK,
        }
    }

    fn as_dyn(&self) -> &dyn Predict {
        match self {
            Predictor::Zero(p) => p,
            Predictor::PLin(p) => p,
            Predictor::EstK(p) => p,
        }
    }

    fn as_dyn_mut(&mut self) -> &mut dyn Predict {
        match self {
            Predictor::Zero(p) => p,
            Predictor::PLin(p) => p,
            Predictor::EstK(p) => p,
        }
    }

    /// Move into a trait object for the new Scheme API.
    pub fn into_box(self) -> Box<dyn Predict> {
        match self {
            Predictor::Zero(p) => Box::new(p),
            Predictor::PLin(p) => Box::new(p),
            Predictor::EstK(p) => Box::new(p),
        }
    }

    pub fn dim(&self) -> usize {
        self.as_dyn().dim()
    }

    /// Current prediction r̂_t.
    pub fn rhat(&self) -> &[f32] {
        self.as_dyn().rhat()
    }

    /// Advance the state given the received quantized update ũ_t.
    pub fn update(&mut self, utilde: &[f32]) {
        self.as_dyn_mut().update(utilde)
    }

    /// Direct state access for the HLO-backend bridge.
    pub fn state_view(&self) -> PredictorState<'_> {
        self.as_dyn().state_view()
    }

    /// Overwrite state from the HLO artifact outputs.
    pub fn load_state(
        &mut self,
        rhat_new: &[f32],
        p_new: Option<&[f32]>,
        s_new: Option<&[f32]>,
        tau_new: Option<&[f32]>,
    ) {
        self.as_dyn_mut().load_state(rhat_new, p_new, s_new, tau_new)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shim_wraps_trait_machines() {
        let mut p = Predictor::new(PredictorKind::PLin, 0.5, 2);
        assert_eq!(p.kind(), PredictorKind::PLin);
        p.update(&[2.0, 0.0]);
        assert_eq!(p.rhat(), &[1.0, 0.0]);
        let b = p.into_box();
        assert_eq!(b.name(), "plin");
        assert_eq!(b.rhat(), &[1.0, 0.0]);
    }

    #[test]
    fn estk_state_accessible_through_variant() {
        let mut p = Predictor::new(PredictorKind::EstK, 0.9, 2);
        p.update(&[0.0, 1.0]);
        match &p {
            Predictor::EstK(e) => {
                assert_eq!(e.tau(), &[1.0, 0.0]);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn worker_master_sync_bit_exact() {
        // both sides fed the same utilde stream -> identical rhat forever
        let mut rng = crate::util::Pcg64::seeded(8);
        for kind in [PredictorKind::PLin, PredictorKind::EstK] {
            let d = 64;
            let mut a = Predictor::new(kind, 0.97, d);
            let mut b = Predictor::new(kind, 0.97, d);
            for _ in 0..200 {
                let ut: Vec<f32> = (0..d)
                    .map(|_| {
                        if rng.uniform() < 0.1 {
                            rng.gaussian() as f32
                        } else {
                            0.0
                        }
                    })
                    .collect();
                a.update(&ut);
                b.update(&ut);
                assert_eq!(a.rhat(), b.rhat());
            }
        }
    }

    #[test]
    fn load_state_roundtrip() {
        let mut p = Predictor::new(PredictorKind::EstK, 0.9, 3);
        p.update(&[1.0, 0.0, -1.0]);
        let rh: Vec<f32> = p.rhat().to_vec();
        let (pp, ss, tt) = match &p {
            Predictor::EstK(e) => (e.p().to_vec(), e.s().to_vec(), e.tau().to_vec()),
            _ => unreachable!(),
        };
        let mut q = Predictor::new(PredictorKind::EstK, 0.9, 3);
        q.load_state(&rh, Some(&pp), Some(&ss), Some(&tt));
        assert_eq!(q.rhat(), p.rhat());
    }
}
