//! Legacy closed-enum quantizer selector — now a thin shim over the open
//! trait objects in [`crate::scheme::quantize`].
//!
//! The numeric bodies live in the trait impls (`NoneQuantizer`,
//! `SignQuantizer`, `TopKQuantizer`, `TopKQQuantizer`, `RandKQuantizer`);
//! every method here dispatches to a stack-constructed trait value, so the
//! enum and trait paths are bit-exact by construction. Prefer
//! [`crate::scheme::Scheme`] / spec strings in new code; this enum stays for
//! config compatibility and the golden-equivalence tests.

use std::sync::Arc;

use crate::coding::PayloadKind;
use crate::scheme::quantize::{
    NoneQuantizer, Quantize, RandKQuantizer, SignQuantizer, TopKQQuantizer, TopKQuantizer,
};

/// Quantizer family and its parameters (deprecated shim; see module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QuantizerKind {
    /// Identity (uncompressed baseline).
    None,
    /// Scaled-sign: mean(|u|) · sign(u).
    Sign,
    /// Top-K sparsification (keep exactly k).
    TopK { k: usize },
    /// Top-K + two-point value quantization.
    TopKQ { k: usize },
    /// Bernoulli Rand-K with shared-seed selection.
    RandK { prob: f32 },
}

impl QuantizerKind {
    /// Dispatch to the trait object on the stack (no allocation).
    fn with_object<R>(&self, f: impl FnOnce(&dyn Quantize) -> R) -> R {
        match *self {
            QuantizerKind::None => f(&NoneQuantizer),
            QuantizerKind::Sign => f(&SignQuantizer),
            QuantizerKind::TopK { k } => f(&TopKQuantizer { k }),
            QuantizerKind::TopKQ { k } => f(&TopKQQuantizer { k }),
            QuantizerKind::RandK { prob } => f(&RandKQuantizer { prob }),
        }
    }

    /// Owned trait object for the new Scheme API.
    pub fn to_object(&self) -> Arc<dyn Quantize> {
        match *self {
            QuantizerKind::None => Arc::new(NoneQuantizer),
            QuantizerKind::Sign => Arc::new(SignQuantizer),
            QuantizerKind::TopK { k } => Arc::new(TopKQuantizer { k }),
            QuantizerKind::TopKQ { k } => Arc::new(TopKQQuantizer { k }),
            QuantizerKind::RandK { prob } => Arc::new(RandKQuantizer { prob }),
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        self.with_object(|q| q.validate())
    }

    pub fn tag(&self) -> String {
        self.with_object(|q| q.tag())
    }

    pub fn payload_kind(&self) -> PayloadKind {
        self.with_object(|q| q.payload_kind())
    }

    /// Quantize `u` into `out` (same length). `round` seeds Rand-K.
    pub fn quantize(&self, u: &[f32], out: &mut [f32], round: u64) {
        self.with_object(|q| q.quantize(u, out, round))
    }

    /// The paper's analytic bits/component for this quantizer at dimension d
    /// (Sec. III-B). Used to sanity-check measured payload sizes.
    pub fn analytic_bits_per_component(&self, d: usize) -> f64 {
        self.with_object(|q| q.analytic_bits_per_component(d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor;
    use crate::util::Pcg64;

    fn randu(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        let mut v = vec![0.0f32; d];
        rng.fill_gaussian(&mut v, 1.0);
        v
    }

    #[test]
    fn none_is_identity() {
        let u = randu(100, 1);
        let mut out = vec![0.0f32; 100];
        QuantizerKind::None.quantize(&u, &mut out, 0);
        assert_eq!(out, u);
    }

    #[test]
    fn sign_scale_and_zeros() {
        let u = vec![2.0f32, -4.0, 0.0, 6.0];
        let mut out = vec![0.0f32; 4];
        QuantizerKind::Sign.quantize(&u, &mut out, 0);
        assert_eq!(out, vec![3.0, -3.0, 0.0, 3.0]);
    }

    #[test]
    fn topk_keeps_exactly_k() {
        let u = randu(1000, 2);
        let mut out = vec![0.0f32; 1000];
        QuantizerKind::TopK { k: 37 }.quantize(&u, &mut out, 0);
        assert_eq!(tensor::nnz(&out), 37);
        // kept values are unmodified
        for i in 0..1000 {
            assert!(out[i] == 0.0 || out[i] == u[i]);
        }
    }

    #[test]
    fn topkq_two_points() {
        let u = randu(500, 3);
        let mut out = vec![0.0f32; 500];
        QuantizerKind::TopKQ { k: 50 }.quantize(&u, &mut out, 0);
        let pos: Vec<f32> = out.iter().copied().filter(|&v| v > 0.0).collect();
        let neg: Vec<f32> = out.iter().copied().filter(|&v| v < 0.0).collect();
        assert!(pos.windows(2).all(|w| w[0] == w[1]));
        assert!(neg.windows(2).all(|w| w[0] == w[1]));
        assert_eq!(tensor::nnz(&out), 50);
    }

    #[test]
    fn topkq_group_mean_minimizes_mse_vs_perturbation() {
        // a+ = mean of kept positives is the MSE-optimal single point
        let u = randu(300, 4);
        let mut out = vec![0.0f32; 300];
        let q = QuantizerKind::TopKQ { k: 60 };
        q.quantize(&u, &mut out, 0);
        let base: f64 = u.iter().zip(&out).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
        for scale in [0.9f32, 1.1] {
            let perturbed: Vec<f32> = out.iter().map(|&v| v * scale).collect();
            let alt: f64 =
                u.iter().zip(&perturbed).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
            assert!(base <= alt + 1e-6);
        }
    }

    #[test]
    fn randk_density() {
        let u = randu(50_000, 5);
        let mut out = vec![0.0f32; 50_000];
        QuantizerKind::RandK { prob: 0.02 }.quantize(&u, &mut out, 9);
        let n = tensor::nnz(&out) as f64;
        assert!((n - 1000.0).abs() < 150.0, "{n}");
    }

    #[test]
    fn delta_compressor_property_topk() {
        // ||x - Q(x)||^2 <= (1 - K/d) ||x||^2 (paper Sec. I-A)
        let mut rng = Pcg64::seeded(6);
        for _ in 0..30 {
            let d = 50 + rng.below(500) as usize;
            let k = 1 + rng.below(d as u64) as usize;
            let u = randu(d, rng.next_u64());
            let mut out = vec![0.0f32; d];
            QuantizerKind::TopK { k }.quantize(&u, &mut out, 0);
            let err: f64 =
                u.iter().zip(&out).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
            let bound = (1.0 - k as f64 / d as f64) * tensor::norm2_sq(&u);
            assert!(err <= bound + 1e-6, "d={d} k={k}");
        }
    }

    #[test]
    fn delta_compressor_property_sign() {
        let mut rng = Pcg64::seeded(7);
        for _ in 0..30 {
            let d = 2 + rng.below(500) as usize;
            let u = randu(d, rng.next_u64());
            let mut out = vec![0.0f32; d];
            QuantizerKind::Sign.quantize(&u, &mut out, 0);
            let err: f64 =
                u.iter().zip(&out).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
            let bound = (1.0 - 1.0 / d as f64) * tensor::norm2_sq(&u);
            assert!(err <= bound + 1e-4, "d={d}");
        }
    }

    #[test]
    fn analytic_rates() {
        assert_eq!(QuantizerKind::None.analytic_bits_per_component(100), 32.0);
        let r = QuantizerKind::TopK { k: 350 }.analytic_bits_per_component(1000);
        assert!((r - 12.13).abs() < 0.05);
    }

    #[test]
    fn validation_via_shim() {
        assert!(QuantizerKind::TopK { k: 0 }.validate().is_err());
        assert!(QuantizerKind::RandK { prob: 2.0 }.validate().is_err());
        assert!(QuantizerKind::Sign.validate().is_ok());
    }
}
