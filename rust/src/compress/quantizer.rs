//! Quantizers Q (paper Eq. (1d)) — dense in, dense out.
//!
//! Semantics mirror `python/compile/kernels/ref.py` exactly (same tie-break
//! for Top-K, sign(0) = 0 for Scaled-sign, mean-of-group reconstruction
//! points for Top-K-Q) so the Rust and HLO backends agree.

use crate::coding::PayloadKind;
use crate::tensor::{self, select_topk_indices};

use super::randk;

/// Quantizer family and its parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QuantizerKind {
    /// Identity (uncompressed baseline).
    None,
    /// Scaled-sign: mean(|u|) · sign(u).
    Sign,
    /// Top-K sparsification (keep exactly k).
    TopK { k: usize },
    /// Top-K + two-point value quantization.
    TopKQ { k: usize },
    /// Bernoulli Rand-K with shared-seed selection.
    RandK { prob: f32 },
}

impl QuantizerKind {
    pub fn validate(&self) -> anyhow::Result<()> {
        match *self {
            QuantizerKind::TopK { k } | QuantizerKind::TopKQ { k } => {
                anyhow::ensure!(k > 0, "top-k requires k > 0");
            }
            QuantizerKind::RandK { prob } => {
                anyhow::ensure!((0.0..=1.0).contains(&prob), "randk prob in [0,1]");
            }
            _ => {}
        }
        Ok(())
    }

    pub fn tag(&self) -> String {
        match *self {
            QuantizerKind::None => "none".into(),
            QuantizerKind::Sign => "sign".into(),
            QuantizerKind::TopK { k } => format!("topk_k{k}"),
            QuantizerKind::TopKQ { k } => format!("topkq_k{k}"),
            QuantizerKind::RandK { prob } => format!("randk_p{prob}").replace('.', "_"),
        }
    }

    pub fn payload_kind(&self) -> PayloadKind {
        match *self {
            QuantizerKind::None => PayloadKind::Dense,
            QuantizerKind::Sign => PayloadKind::Sign,
            QuantizerKind::TopK { .. } => PayloadKind::SparseValues,
            QuantizerKind::TopKQ { .. } => PayloadKind::SparseTwoPoint,
            QuantizerKind::RandK { prob } => PayloadKind::MaskedValues { prob },
        }
    }

    /// Quantize `u` into `out` (same length). `round` seeds Rand-K.
    pub fn quantize(&self, u: &[f32], out: &mut [f32], round: u64) {
        debug_assert_eq!(u.len(), out.len());
        match *self {
            QuantizerKind::None => out.copy_from_slice(u),
            QuantizerKind::Sign => {
                let a = tensor::mean_abs(u);
                for (o, &v) in out.iter_mut().zip(u) {
                    *o = if v > 0.0 {
                        a
                    } else if v < 0.0 {
                        -a
                    } else {
                        0.0
                    };
                }
            }
            QuantizerKind::TopK { k } => {
                out.fill(0.0);
                for &i in &select_topk_indices(u, k) {
                    out[i as usize] = u[i as usize];
                }
            }
            QuantizerKind::TopKQ { k } => {
                out.fill(0.0);
                let idx = select_topk_indices(u, k);
                let (mut pos_sum, mut npos) = (0.0f64, 0u32);
                let (mut neg_sum, mut nneg) = (0.0f64, 0u32);
                for &i in &idx {
                    let v = u[i as usize];
                    if v > 0.0 {
                        pos_sum += v as f64;
                        npos += 1;
                    } else if v < 0.0 {
                        neg_sum += (-v) as f64;
                        nneg += 1;
                    }
                }
                // f32 group means, matching the jnp reference reduction order
                // closely enough (values only, no index-dependent ops)
                let a_pos = if npos > 0 { (pos_sum / npos as f64) as f32 } else { 0.0 };
                let a_neg = if nneg > 0 { (neg_sum / nneg as f64) as f32 } else { 0.0 };
                for &i in &idx {
                    let v = u[i as usize];
                    if v > 0.0 {
                        out[i as usize] = a_pos;
                    } else if v < 0.0 {
                        out[i as usize] = -a_neg;
                    }
                }
            }
            QuantizerKind::RandK { prob } => randk::apply(u, out, round, prob),
        }
    }

    /// The paper's analytic bits/component for this quantizer at dimension d
    /// (Sec. III-B). Used to sanity-check measured payload sizes.
    pub fn analytic_bits_per_component(&self, d: usize) -> f64 {
        match *self {
            QuantizerKind::None => 32.0,
            QuantizerKind::Sign => 1.0 + 32.0 / d as f64,
            QuantizerKind::TopK { k } => crate::util::topk_bits_per_component(k.min(d), d),
            QuantizerKind::TopKQ { k } => {
                // ternary entropy with the +/- split unknown a priori; use
                // the symmetric worst case k/2 each plus the two scales
                let kk = k.min(d);
                crate::util::topkq_bits_per_component(kk / 2, kk - kk / 2, d) + 64.0 / d as f64
            }
            QuantizerKind::RandK { prob } => 32.0 * prob as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn randu(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        let mut v = vec![0.0f32; d];
        rng.fill_gaussian(&mut v, 1.0);
        v
    }

    #[test]
    fn none_is_identity() {
        let u = randu(100, 1);
        let mut out = vec![0.0f32; 100];
        QuantizerKind::None.quantize(&u, &mut out, 0);
        assert_eq!(out, u);
    }

    #[test]
    fn sign_scale_and_zeros() {
        let u = vec![2.0f32, -4.0, 0.0, 6.0];
        let mut out = vec![0.0f32; 4];
        QuantizerKind::Sign.quantize(&u, &mut out, 0);
        assert_eq!(out, vec![3.0, -3.0, 0.0, 3.0]);
    }

    #[test]
    fn topk_keeps_exactly_k() {
        let u = randu(1000, 2);
        let mut out = vec![0.0f32; 1000];
        QuantizerKind::TopK { k: 37 }.quantize(&u, &mut out, 0);
        assert_eq!(tensor::nnz(&out), 37);
        // kept values are unmodified
        for i in 0..1000 {
            assert!(out[i] == 0.0 || out[i] == u[i]);
        }
    }

    #[test]
    fn topkq_two_points() {
        let u = randu(500, 3);
        let mut out = vec![0.0f32; 500];
        QuantizerKind::TopKQ { k: 50 }.quantize(&u, &mut out, 0);
        let pos: Vec<f32> = out.iter().copied().filter(|&v| v > 0.0).collect();
        let neg: Vec<f32> = out.iter().copied().filter(|&v| v < 0.0).collect();
        assert!(pos.windows(2).all(|w| w[0] == w[1]));
        assert!(neg.windows(2).all(|w| w[0] == w[1]));
        assert_eq!(tensor::nnz(&out), 50);
    }

    #[test]
    fn topkq_group_mean_minimizes_mse_vs_perturbation() {
        // a+ = mean of kept positives is the MSE-optimal single point
        let u = randu(300, 4);
        let mut out = vec![0.0f32; 300];
        let q = QuantizerKind::TopKQ { k: 60 };
        q.quantize(&u, &mut out, 0);
        let base: f64 = u.iter().zip(&out).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
        for scale in [0.9f32, 1.1] {
            let perturbed: Vec<f32> = out.iter().map(|&v| v * scale).collect();
            let alt: f64 =
                u.iter().zip(&perturbed).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
            assert!(base <= alt + 1e-6);
        }
    }

    #[test]
    fn randk_density() {
        let u = randu(50_000, 5);
        let mut out = vec![0.0f32; 50_000];
        QuantizerKind::RandK { prob: 0.02 }.quantize(&u, &mut out, 9);
        let n = tensor::nnz(&out) as f64;
        assert!((n - 1000.0).abs() < 150.0, "{n}");
    }

    #[test]
    fn delta_compressor_property_topk() {
        // ||x - Q(x)||^2 <= (1 - K/d) ||x||^2 (paper Sec. I-A)
        let mut rng = Pcg64::seeded(6);
        for _ in 0..30 {
            let d = 50 + rng.below(500) as usize;
            let k = 1 + rng.below(d as u64) as usize;
            let u = randu(d, rng.next_u64());
            let mut out = vec![0.0f32; d];
            QuantizerKind::TopK { k }.quantize(&u, &mut out, 0);
            let err: f64 =
                u.iter().zip(&out).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
            let bound = (1.0 - k as f64 / d as f64) * tensor::norm2_sq(&u);
            assert!(err <= bound + 1e-6, "d={d} k={k}");
        }
    }

    #[test]
    fn delta_compressor_property_sign() {
        let mut rng = Pcg64::seeded(7);
        for _ in 0..30 {
            let d = 2 + rng.below(500) as usize;
            let u = randu(d, rng.next_u64());
            let mut out = vec![0.0f32; d];
            QuantizerKind::Sign.quantize(&u, &mut out, 0);
            let err: f64 =
                u.iter().zip(&out).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
            let bound = (1.0 - 1.0 / d as f64) * tensor::norm2_sq(&u);
            assert!(err <= bound + 1e-4, "d={d}");
        }
    }

    #[test]
    fn analytic_rates() {
        assert_eq!(QuantizerKind::None.analytic_bits_per_component(100), 32.0);
        let r = QuantizerKind::TopK { k: 350 }.analytic_bits_per_component(1000);
        assert!((r - 12.13).abs() < 0.05);
    }
}
