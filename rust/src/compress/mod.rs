//! The paper's compression algorithms (pure-Rust reference backend).
//!
//! * [`SchemeCfg`] — a point in the design space: quantizer × predictor ×
//!   error-feedback × β (paper Fig. 2 with the EF switch and blue blocks).
//! * [`quantizer`] — Q: Top-K, Top-K-Q, Scaled-sign, Rand-K, identity.
//! * [`predictor`] — P: Zero, P_Lin (Eq. 4), Est-K (Alg. 1).
//! * [`pipeline`] — the full worker box (Eq. (1)) and the master-side
//!   decode-and-predict chain, kept in bit-exact sync.
//!
//! The same step is also available as an AOT-compiled HLO artifact built
//! from the Pallas kernels (see `runtime::CompressExec`); integration tests
//! assert the two backends agree elementwise.

pub mod pipeline;
pub mod predictor;
pub mod quantizer;
pub mod randk;

pub use pipeline::{MasterChain, StepStats, WorkerPipeline};
pub use predictor::Predictor;
pub use quantizer::QuantizerKind;

use crate::coding::PayloadKind;

/// Which predictor P to run (paper Sec. III-A, IV-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictorKind {
    /// No prediction (removes the blue blocks in Fig. 2).
    Zero,
    /// P_Lin(r̃) = β·r̃ — the DPCM first-order predictor (Eq. 4).
    PLin,
    /// Est-K — momentum estimate/extrapolate between Top-K peaks (Alg. 1).
    EstK,
}

impl PredictorKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            PredictorKind::Zero => "zero",
            PredictorKind::PLin => "plin",
            PredictorKind::EstK => "estk",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "zero" | "none" => PredictorKind::Zero,
            "plin" | "lin" => PredictorKind::PLin,
            "estk" => PredictorKind::EstK,
            _ => anyhow::bail!("unknown predictor {s:?} (zero|plin|estk)"),
        })
    }
}

/// Full scheme configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct SchemeCfg {
    pub quantizer: QuantizerKind,
    pub predictor: PredictorKind,
    /// Error-feedback switch (paper Eq. (1b)).
    pub ef: bool,
    /// Momentum / LPF bandwidth parameter β ∈ [0, 1).
    pub beta: f32,
}

impl SchemeCfg {
    pub fn new(quantizer: QuantizerKind, predictor: PredictorKind, ef: bool, beta: f32) -> anyhow::Result<Self> {
        let cfg = Self { quantizer, predictor, ef, beta };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Uncompressed momentum-SGD baseline (Table I row 1).
    pub fn baseline(beta: f32) -> Self {
        Self { quantizer: QuantizerKind::None, predictor: PredictorKind::Zero, ef: false, beta }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (0.0..1.0).contains(&self.beta),
            "beta must be in [0,1), got {}",
            self.beta
        );
        if self.predictor == PredictorKind::EstK {
            anyhow::ensure!(
                matches!(self.quantizer, QuantizerKind::TopK { .. }),
                "Est-K is defined only on top of the Top-K quantizer (paper Sec. IV-C)"
            );
        }
        self.quantizer.validate()
    }

    /// Wire format for this scheme's messages.
    pub fn payload_kind(&self) -> PayloadKind {
        self.quantizer.payload_kind()
    }

    /// Human-readable tag, mirrors the python `Scheme.tag` naming.
    pub fn tag(&self) -> String {
        format!(
            "{}_{}_{}_b{}",
            self.quantizer.tag(),
            self.predictor.as_str(),
            if self.ef { "ef" } else { "noef" },
            fmt_beta(self.beta),
        )
    }
}

fn fmt_beta(beta: f32) -> String {
    format!("{beta}").replace('.', "_")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_rules() {
        assert!(SchemeCfg::new(QuantizerKind::None, PredictorKind::Zero, false, 0.9).is_ok());
        // Est-K requires Top-K
        assert!(SchemeCfg::new(QuantizerKind::Sign, PredictorKind::EstK, true, 0.9).is_err());
        assert!(
            SchemeCfg::new(QuantizerKind::TopK { k: 10 }, PredictorKind::EstK, true, 0.9).is_ok()
        );
        // beta range
        assert!(SchemeCfg::new(QuantizerKind::None, PredictorKind::Zero, false, 1.0).is_err());
        // k = 0 invalid
        assert!(SchemeCfg::new(QuantizerKind::TopK { k: 0 }, PredictorKind::Zero, false, 0.9).is_err());
    }

    #[test]
    fn tags_distinct() {
        let a = SchemeCfg::new(QuantizerKind::TopK { k: 5 }, PredictorKind::Zero, true, 0.99).unwrap();
        let b = SchemeCfg::new(QuantizerKind::TopK { k: 5 }, PredictorKind::EstK, true, 0.99).unwrap();
        assert_ne!(a.tag(), b.tag());
        assert!(a.tag().contains("ef"));
    }

    #[test]
    fn predictor_parse_roundtrip() {
        for p in [PredictorKind::Zero, PredictorKind::PLin, PredictorKind::EstK] {
            assert_eq!(PredictorKind::parse(p.as_str()).unwrap(), p);
        }
        assert!(PredictorKind::parse("bogus").is_err());
    }
}
