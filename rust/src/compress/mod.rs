//! The paper's compression algorithms (pure-Rust reference backend).
//!
//! The open, composable API lives in [`crate::scheme`] (traits + registry +
//! spec strings); this module holds the Eq.-(1) pipeline machinery built on
//! it, plus the legacy closed-enum configuration kept as a thin shim:
//!
//! * [`pipeline`] — the full worker box (Eq. (1)) and the master-side
//!   decode-and-predict chain, generic over `dyn Quantize`/`dyn Predict`
//!   and kept in bit-exact sync across worker and master.
//! * [`quantizer`] / [`predictor`] — **deprecated shims**: the old
//!   `QuantizerKind` / `Predictor` enums, now delegating into the trait
//!   objects so every match arm disappeared from the hot path. Kept so
//!   existing configs, tests and the HLO-equivalence suite stay source- and
//!   bit-compatible. New code should use `scheme::Scheme` / spec strings.
//! * [`SchemeCfg`] — **deprecated shim**: quantizer × predictor × EF × β as
//!   plain data; [`SchemeCfg::to_scheme`] forwards into the registry.
//! * [`randk`] — shared-seed Bernoulli mask helpers (used by the Rand-K
//!   quantizer and the `MaskedValues` wire format).
//!
//! The same step is also available as an AOT-compiled HLO artifact built
//! from the Pallas kernels (see `runtime::CompressExec`); integration tests
//! assert the two backends agree elementwise.

pub mod pipeline;
pub mod predictor;
pub mod quantizer;
pub mod randk;

pub use pipeline::{MasterChain, StepStats, WorkerPipeline};
pub use predictor::Predictor;
pub use quantizer::QuantizerKind;

use crate::coding::PayloadKind;
use crate::scheme::{Predict, QuantParams, Scheme, SchemeRegistry};

/// Which predictor P to run (paper Sec. III-A, IV-C). Deprecated shim —
/// predictors are open via `scheme::SchemeRegistry::register_predictor`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictorKind {
    /// No prediction (removes the blue blocks in Fig. 2).
    Zero,
    /// P_Lin(r̃) = β·r̃ — the DPCM first-order predictor (Eq. 4).
    PLin,
    /// Est-K — momentum estimate/extrapolate between Top-K peaks (Alg. 1).
    EstK,
}

impl PredictorKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            PredictorKind::Zero => "zero",
            PredictorKind::PLin => "plin",
            PredictorKind::EstK => "estk",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "zero" | "none" => PredictorKind::Zero,
            "plin" | "lin" => PredictorKind::PLin,
            "estk" => PredictorKind::EstK,
            _ => anyhow::bail!("unknown predictor {s:?} (zero|plin|estk)"),
        })
    }

    /// Owned trait object for the new Scheme API.
    pub fn to_object(&self, beta: f32, d: usize) -> Box<dyn Predict> {
        Predictor::new(*self, beta, d).into_box()
    }
}

/// Full scheme configuration. Deprecated shim over [`crate::scheme::Scheme`]
/// — kept for config compatibility and the golden-equivalence tests;
/// [`Self::to_scheme`] forwards into the registry.
#[derive(Clone, Debug, PartialEq)]
pub struct SchemeCfg {
    pub quantizer: QuantizerKind,
    pub predictor: PredictorKind,
    /// Error-feedback switch (paper Eq. (1b)).
    pub ef: bool,
    /// Momentum / LPF bandwidth parameter β ∈ [0, 1).
    pub beta: f32,
}

impl SchemeCfg {
    pub fn new(quantizer: QuantizerKind, predictor: PredictorKind, ef: bool, beta: f32) -> anyhow::Result<Self> {
        let cfg = Self { quantizer, predictor, ef, beta };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Uncompressed momentum-SGD baseline (Table I row 1).
    pub fn baseline(beta: f32) -> Self {
        Self { quantizer: QuantizerKind::None, predictor: PredictorKind::Zero, ef: false, beta }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (0.0..1.0).contains(&self.beta),
            "beta must be in [0,1), got {}",
            self.beta
        );
        if self.predictor == PredictorKind::EstK {
            anyhow::ensure!(
                matches!(self.quantizer, QuantizerKind::TopK { .. }),
                "Est-K is defined only on top of the Top-K quantizer (paper Sec. IV-C)"
            );
        }
        self.quantizer.validate()
    }

    /// Wire format for this scheme's messages.
    pub fn payload_kind(&self) -> PayloadKind {
        self.quantizer.payload_kind()
    }

    /// Forward into the registry-backed Scheme API. Panics only on
    /// configurations [`Self::validate`] rejects (e.g. β outside [0,1)).
    pub fn to_scheme(&self) -> Scheme {
        let mut params = QuantParams::new();
        let qname = match self.quantizer {
            QuantizerKind::None => "none",
            QuantizerKind::Sign => "sign",
            QuantizerKind::TopK { k } => {
                params.insert("k".to_string(), k as f64);
                "topk"
            }
            QuantizerKind::TopKQ { k } => {
                params.insert("k".to_string(), k as f64);
                "topkq"
            }
            QuantizerKind::RandK { prob } => {
                params.insert("p".to_string(), prob as f64);
                "randk"
            }
        };
        SchemeRegistry::global()
            .single(qname, params, self.predictor.as_str(), self.ef, self.beta)
            .expect("SchemeCfg maps onto built-in registry entries")
    }

    /// Human-readable tag, mirrors the python `Scheme.tag` naming.
    pub fn tag(&self) -> String {
        format!(
            "{}_{}_{}_b{}",
            self.quantizer.tag(),
            self.predictor.as_str(),
            if self.ef { "ef" } else { "noef" },
            fmt_beta(self.beta),
        )
    }
}

fn fmt_beta(beta: f32) -> String {
    format!("{beta}").replace('.', "_")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_rules() {
        assert!(SchemeCfg::new(QuantizerKind::None, PredictorKind::Zero, false, 0.9).is_ok());
        // Est-K requires Top-K
        assert!(SchemeCfg::new(QuantizerKind::Sign, PredictorKind::EstK, true, 0.9).is_err());
        assert!(
            SchemeCfg::new(QuantizerKind::TopK { k: 10 }, PredictorKind::EstK, true, 0.9).is_ok()
        );
        // beta range
        assert!(SchemeCfg::new(QuantizerKind::None, PredictorKind::Zero, false, 1.0).is_err());
        // k = 0 invalid
        assert!(SchemeCfg::new(QuantizerKind::TopK { k: 0 }, PredictorKind::Zero, false, 0.9).is_err());
    }

    #[test]
    fn tags_distinct() {
        let a = SchemeCfg::new(QuantizerKind::TopK { k: 5 }, PredictorKind::Zero, true, 0.99).unwrap();
        let b = SchemeCfg::new(QuantizerKind::TopK { k: 5 }, PredictorKind::EstK, true, 0.99).unwrap();
        assert_ne!(a.tag(), b.tag());
        assert!(a.tag().contains("ef"));
    }

    #[test]
    fn predictor_parse_roundtrip() {
        for p in [PredictorKind::Zero, PredictorKind::PLin, PredictorKind::EstK] {
            assert_eq!(PredictorKind::parse(p.as_str()).unwrap(), p);
        }
        assert!(PredictorKind::parse("bogus").is_err());
    }

    #[test]
    fn to_scheme_forwards_into_registry() {
        let cfg = SchemeCfg::new(
            QuantizerKind::RandK { prob: 0.25 },
            PredictorKind::PLin,
            false,
            0.9,
        )
        .unwrap();
        let s = cfg.to_scheme();
        assert_eq!(s.spec(), "randk:p=0.25/plin/noef/beta=0.9");
        assert!(s.worker(32).is_ok());
    }
}
