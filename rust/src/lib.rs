//! # tempo — temporal-correlation gradient compression for momentum-SGD
//!
//! A three-layer (Rust coordinator + JAX graphs + Pallas kernels, AOT via
//! PJRT) reproduction of Adikari & Draper, *"Compressing gradients by
//! exploiting temporal correlation in momentum-SGD"*, IEEE JSAIT 2021.
//!
//! The crate is organised bottom-up:
//!
//! * [`util`] — RNG (PCG64 / SplitMix64), statistics, timers.
//! * [`tensor`] — flat f32 vector kernels used on the coordinator hot path.
//! * [`coding`] — bit-level entropy coding (Golomb–Rice, Elias, sign-pack)
//!   and the per-quantizer wire payload formats.
//! * [`compress`] — the paper's algorithms: quantizers (Top-K, Top-K-Q,
//!   Scaled-sign, Rand-K), predictors (P_Lin, Est-K), error-feedback, and
//!   the full Fig.-2 worker pipeline.
//! * [`optim`] — LR schedules and the parameter update rule.
//! * [`data`] — synthetic ImageNet-32 stand-in + Markov text corpus.
//! * [`config`] — TOML-subset/JSON parsers and typed experiment configs.
//! * [`model`] — the artifact-backed model zoo (reads artifacts/manifest.json).
//! * [`runtime`] — PJRT client wrapper: load HLO text, compile, execute.
//! * [`comm`] — transports (in-process channels, TCP) with byte accounting
//!   and a simulated network cost model.
//! * [`coordinator`] — master/worker round loop (the paper's system).
//! * [`metrics`] — meters, CSV/JSONL run logs.
//! * [`experiments`] — one driver per paper table/figure (see DESIGN.md §4).
//! * [`testing`] — in-repo property-testing + bench harness (offline build).

pub mod cli;
pub mod coding;
pub mod comm;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
