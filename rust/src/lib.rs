//! # tempo — temporal-correlation gradient compression for momentum-SGD
//!
//! A three-layer (Rust coordinator + JAX graphs + Pallas kernels, AOT via
//! PJRT) reproduction of Adikari & Draper, *"Compressing gradients by
//! exploiting temporal correlation in momentum-SGD"*, IEEE JSAIT 2021.
//!
//! The crate is organised bottom-up:
//!
//! * [`util`] — RNG (PCG64 / SplitMix64), statistics, timers.
//! * [`tensor`] — flat f32 vector kernels used on the coordinator hot path.
//! * [`coding`] — bit-level entropy coding (Golomb–Rice, Elias, sign-pack)
//!   and the per-quantizer wire payload formats.
//! * [`scheme`] — **the compression Scheme API**: open `Quantize` /
//!   `Predict` / `PayloadCodec` traits, the `SchemeRegistry` resolving spec
//!   strings (`topk:k=128/estk/ef/beta=0.9`) into built pipelines, and the
//!   `blocks(...)` combinator for per-block sub-schemes. New schemes plug
//!   in here — one file, no cross-cutting enum edits.
//! * [`compress`] — the Eq.-(1) worker pipeline and master chain built on
//!   the scheme traits, plus the deprecated `SchemeCfg`/`QuantizerKind`
//!   enum shims kept for config and golden-test compatibility.
//! * [`optim`] — LR schedules and the parameter update rule.
//! * [`data`] — synthetic ImageNet-32 stand-in + Markov text corpus.
//! * [`config`] — TOML-subset/JSON parsers and typed experiment configs
//!   (scheme spec strings ride the `[scheme] spec = "..."` key).
//! * [`model`] — the artifact-backed model zoo (reads artifacts/manifest.json).
//! * [`runtime`] — PJRT client wrapper: load HLO text, compile, execute.
//!   Builds against the vendored `xla` stub offline; see vendor/README.md.
//! * [`comm`] — transports (in-process channels, TCP) with byte accounting
//!   and a simulated network cost model.
//! * [`coordinator`] — master/worker round loop (the paper's system) with
//!   injectable gradient sources and a headless master for model-free runs.
//! * [`metrics`] — meters, CSV/JSONL run logs, per-block comm accounting.
//! * [`experiments`] — one driver per paper table/figure (see DESIGN.md §5).
//! * [`testing`] — in-repo property-testing + bench harness (offline build)
//!   and the artifact/PJRT availability gates for integration tests.

// The numeric kernels deliberately use index loops that mirror the Pallas
// reference layout (same op order => bit-exact HLO parity), which trips
// clippy's style-only range-loop/copy lints; trait builders take registry
// closures whose types are necessarily long.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod cli;
pub mod coding;
pub mod comm;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod scheme;
pub mod tensor;
pub mod testing;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
