//! Theorem 1 / Corollary 1 numeric validation as a standalone example.
//!
//! ```bash
//! cargo run --release --offline --example convergence_validation
//! ```

use tempo::experiments::{theorem1, ExpOptions};

fn main() -> anyhow::Result<()> {
    let opts = ExpOptions { smoke: false, out_dir: "results".into(), seed: 0 };
    std::fs::create_dir_all(&opts.out_dir).ok();
    theorem1::run(&opts)
}
