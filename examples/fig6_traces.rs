//! The paper's §IV-B illustrative synthetic experiment (Fig. 6) as a
//! standalone example: single-component traces of v, u, ũ, r̂ under Top-K
//! with and without the Est-K predictor.
//!
//! ```bash
//! cargo run --release --offline --example fig6_traces
//! ```

use tempo::experiments::{fig6_synthetic, ExpOptions};

fn main() -> anyhow::Result<()> {
    let opts = ExpOptions { smoke: false, out_dir: "results".into(), seed: 0 };
    std::fs::create_dir_all(&opts.out_dir).ok();
    fig6_synthetic::run(&opts)
}
