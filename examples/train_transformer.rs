//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): distributed training of a
//! decoder-only transformer LM on the Markov corpus with Est-K-compressed
//! updates, logging the loss curve. Default model: lm_tiny (stable at this
//! CPU-budget horizon); pass --model lm_small for the 0.86M-param variant —
//! note EXPERIMENTS.md §E2E on EF-burst instability for deep models at
//! sparse K (transformers are outside the paper's evaluated families).
//!
//! Exercises every layer at once: L1 Pallas kernels (fused bias+GELU inside
//! the model, the fused compress step via the HLO backend on worker 0-path
//! configs), L2 JAX fwd/bwd lowered AOT, L3 rust coordinator with entropy-
//! coded worker→master traffic.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example train_transformer [-- --steps 300]
//! ```

use tempo::cli::Args;
use tempo::config::{ExperimentConfig, SchemeSpec};
use tempo::coordinator::run_training;
use tempo::metrics::{CsvWriter, RunPoint};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.u64_flag("steps", 300)?;
    let model = args.flag_or("model", "lm_tiny");

    let mut cfg = ExperimentConfig::default();
    cfg.name = "e2e_train_transformer".into();
    cfg.model = model.clone();
    cfg.workers = args.usize_flag("workers", 2)?;
    cfg.steps = steps;
    cfg.eval_every = (steps / 12).max(1);
    cfg.eval_batches = 2;
    cfg.train_len = 8192;
    // 0.5 is stable for lm_tiny but diverges the 0.86M-param lm_small;
    // 0.1 + warmup holds for both (override with --lr)
    cfg.lr = args.f64_flag("lr", if model == "lm_tiny" { 0.3 } else { 0.02 })? as f32;
    cfg.warmup = 20;
    cfg.clip_norm = 1.0; // lm_small spikes past ~round 250 without clipping
    cfg.seed = 11;
    // β = 0.9 keeps the Est-K extrapolation memory (~1/(1-β) = 10 rounds)
    // far below the Top-K revisit gap, so stale dense predictions decay to
    // zero between revisits instead of drifting the 0.86M-param LM — at
    // β = 0.99 the same configuration destabilizes after ~250 rounds (the
    // horizon/gap tradeoff documented with Fig. 8; transformers are outside
    // the paper's evaluated models).
    cfg.scheme = SchemeSpec {
        quantizer: "topk".into(),
        predictor: "estk".into(),
        ef: true,
        beta: args.f64_flag("beta", 0.9)? as f32,
        k_frac: Some(args.f64_flag("k-frac", 2.0e-2)?),
        ..Default::default()
    };

    println!(
        "e2e: training {model} ({} workers, {} steps, Top-K+Est-K+EF)",
        cfg.workers, cfg.steps
    );
    let t0 = std::time::Instant::now();
    let report = run_training(&cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nloss curve:");
    println!("{:<8} {:>12} {:>12} {:>10} {:>12}", "step", "train_loss", "test_loss", "tok_acc", "bits/comp");
    for p in &report.points {
        println!(
            "{:<8} {:>12.4} {:>12.4} {:>10.3} {:>12.4}",
            p.step, p.train_loss, p.test_loss, p.test_acc, p.bits_per_component
        );
    }

    let path = "results/e2e_transformer_loss.csv";
    let mut w = CsvWriter::create(path, RunPoint::csv_header())?;
    for p in &report.points {
        w.row(&p.to_csv_row())?;
    }
    w.flush()?;

    let first = report.points.first().unwrap();
    let last = report.points.last().unwrap();
    println!("\nsummary:");
    println!("  wall time          {wall:.1}s ({:.0} ms/round)", wall * 1e3 / cfg.steps as f64);
    println!("  train loss         {:.4} -> {:.4}", first.train_loss, last.train_loss);
    println!("  test loss          {:.4} -> {:.4}  (uniform baseline = ln(vocab))", first.test_loss, last.test_loss);
    println!("  next-token acc     {:.3}", report.final_test_acc);
    println!("  uplink rate        {:.4} bits/component ({:.0}x vs fp32)",
             report.bits_per_component, report.compression_ratio);
    println!("  worker phases (ms): gradient {:.1} | compress {:.2} | encode {:.3}",
             report.worker_phases.mean("gradient") * 1e3,
             report.worker_phases.mean("compress") * 1e3,
             report.worker_phases.mean("encode") * 1e3);
    println!("  loss log: {path}");
    anyhow::ensure!(
        last.train_loss < first.train_loss,
        "training did not reduce the loss"
    );
    Ok(())
}
