//! Compression playground: stream synthetic (temporally-correlated)
//! gradients through every scheme and compare measured wire rate,
//! reconstruction error, and the prediction effect — no PJRT needed.
//!
//! ```bash
//! cargo run --release --offline --example compression_playground [-- --d 100000 --steps 300]
//! ```

use tempo::cli::Args;
use tempo::coding::encode_payload;
use tempo::compress::{PredictorKind, QuantizerKind, SchemeCfg, WorkerPipeline};
use tempo::experiments::common::GradStream;
use tempo::util::binary_entropy;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let d = args.usize_flag("d", 50_000)?;
    let steps = args.usize_flag("steps", 200)?;
    let beta = args.f64_flag("beta", 0.99)? as f32;
    let k = (d / 200).max(1);

    let schemes: Vec<(&str, SchemeCfg)> = vec![
        ("baseline fp32", SchemeCfg::baseline(beta)),
        ("scaled-sign", SchemeCfg::new(QuantizerKind::Sign, PredictorKind::Zero, false, beta)?),
        ("scaled-sign + P_Lin", SchemeCfg::new(QuantizerKind::Sign, PredictorKind::PLin, false, beta)?),
        ("top-k", SchemeCfg::new(QuantizerKind::TopK { k }, PredictorKind::Zero, false, beta)?),
        ("top-k + P_Lin", SchemeCfg::new(QuantizerKind::TopK { k }, PredictorKind::PLin, false, beta)?),
        ("top-k-q + P_Lin", SchemeCfg::new(QuantizerKind::TopKQ { k }, PredictorKind::PLin, false, beta)?),
        ("rand-k", SchemeCfg::new(QuantizerKind::RandK { prob: k as f32 / d as f32 }, PredictorKind::Zero, false, beta)?),
        ("EF top-k", SchemeCfg::new(QuantizerKind::TopK { k }, PredictorKind::Zero, true, beta)?),
        ("EF top-k + Est-K", SchemeCfg::new(QuantizerKind::TopK { k }, PredictorKind::EstK, true, beta)?),
    ];

    println!("compression playground: d={d}, K={k} (K/d={:.4}), beta={beta}, {steps} steps", k as f64 / d as f64);
    println!("analytic Top-K rate: H_b(K/d)+32K/d = {:.4} bits/comp\n",
             binary_entropy(k as f64 / d as f64) + 32.0 * k as f64 / d as f64);
    println!("{:<22} {:>12} {:>14} {:>14} {:>10}", "scheme", "bits/comp", "mean ||e||²/d", "mean ||u||²/d", "nnz/step");

    for (label, cfg) in schemes {
        let mut stream = GradStream::correlated(d, 42, 1.0, 0.5);
        let payload_kind = cfg.payload_kind();
        let mut pipe = WorkerPipeline::new(cfg, d);
        let (mut bits, mut emse, mut unorm, mut nnz) = (0u64, 0.0f64, 0.0f64, 0usize);
        for t in 0..steps {
            let g = stream.next().to_vec();
            let stats = pipe.step(&g, if t == 0 { 0.0 } else { 1.0 });
            bits += encode_payload(payload_kind, pipe.utilde(), t as u64).bits;
            emse += stats.e_mse;
            unorm += stats.u_norm_sq / d as f64;
            nnz += stats.nnz;
        }
        println!(
            "{:<22} {:>12.4} {:>14.4e} {:>14.4e} {:>10}",
            label,
            bits as f64 / (steps as f64 * d as f64),
            emse / steps as f64,
            unorm / steps as f64,
            nnz / steps
        );
    }
    println!("\n(observe: predictors shrink ||u||² and therefore ||e||²; Est-K");
    println!(" keeps the EF system stable where P_Lin would diverge — see fig5)");
    Ok(())
}
