//! Quickstart: distributed momentum-SGD with Est-K compressed updates in
//! ~30 lines of public API.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use tempo::config::{ExperimentConfig, SchemeSpec};
use tempo::coordinator::run_training;

fn main() -> anyhow::Result<()> {
    // 1. pick a model from the artifact manifest and a compression scheme
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp_tiny".into(); // d = 98,666 parameters
    cfg.workers = 2;
    cfg.steps = 100;
    cfg.eval_every = 25;
    cfg.train_len = 2048;
    cfg.noise = 6.0;
    cfg.scheme = SchemeSpec {
        quantizer: "topk".into(), // Top-K sparsification ...
        predictor: "estk".into(), // ... + the paper's Est-K predictor
        ef: true,                 // ... with error-feedback
        beta: 0.99,               // momentum = temporal correlation source
        k_frac: Some(2.0e-3),     // K = 0.002 d
        ..Default::default()
    };

    // 2. run master + workers (PJRT model execution, entropy-coded wire)
    let report = run_training(&cfg)?;

    // 3. read the results
    for p in &report.points {
        println!(
            "step {:>4}  train_loss {:.4}  test_acc {:.3}  bits/component {:.4}",
            p.step, p.train_loss, p.test_acc, p.bits_per_component
        );
    }
    println!(
        "\ncompressed to {:.4} bits/component = {:.0}x smaller than fp32, final acc {:.3}",
        report.bits_per_component, report.compression_ratio, report.final_test_acc
    );
    Ok(())
}
