#!/usr/bin/env bash
# Tier-1 verification entry point (documented in ROADMAP.md / DESIGN.md).
#
#   scripts/ci.sh          # fmt + clippy + release build + tests
#   scripts/ci.sh --fast   # skip fmt/clippy (build + tests only)
#
# Everything runs offline: the workspace vendors `anyhow` and stubs the
# `xla` PJRT bindings (rust/vendor/README.md); integration tests that need
# real artifacts self-skip with a SKIP message.
set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
if [[ "${1:-}" == "--fast" ]]; then
    FAST=1
fi

if [[ "$FAST" -eq 0 ]]; then
    echo "== cargo fmt --check =="
    cargo fmt --check
    echo "== cargo clippy (deny warnings) =="
    cargo clippy --workspace --all-targets -- -D warnings
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "ci.sh: all green"
