#!/usr/bin/env bash
# Tier-1 verification entry point (documented in ROADMAP.md / DESIGN.md).
#
#   scripts/ci.sh                      # fmt + clippy + release build + tests
#   scripts/ci.sh --fast               # skip fmt/clippy (build + tests only)
#   scripts/ci.sh --bench              # run the [[bench]] targets in smoke
#                                      # mode and write BENCH_<N>.json
#   scripts/ci.sh --bench --bench-filter <s>
#                                      # run only benches matching <s>: if a
#                                      # bench *target* name matches, run
#                                      # just those targets; otherwise pass
#                                      # the substring down as a per-bench
#                                      # name filter. No trajectory point is
#                                      # written for filtered runs.
#
# Everything runs offline: the workspace vendors `anyhow` and stubs the
# `xla` PJRT bindings (rust/vendor/README.md); integration tests and the
# PJRT benches self-skip with a SKIP message when artifacts are absent.
#
# Every phase is wall-clocked; the summary lines are grep-able as
# `^ci-phase ` (CI surfaces them without parsing cargo output). Bench mode
# additionally emits an aggregate `ci-phase bench` line covering the whole
# bench stage.
set -euo pipefail
cd "$(dirname "$0")/.."

MODE="full"
BENCH_FILTER=""
while [[ $# -gt 0 ]]; do
    case "$1" in
        --fast)  MODE="fast" ;;
        --bench) MODE="bench" ;;
        --bench-filter)
            shift
            BENCH_FILTER="${1:-}"
            [[ -n "$BENCH_FILTER" ]] || { echo "--bench-filter needs a value" >&2; exit 2; }
            ;;
        *) echo "usage: scripts/ci.sh [--fast|--bench] [--bench-filter <substr>]" >&2; exit 2 ;;
    esac
    shift
done

if [[ -n "$BENCH_FILTER" && "$MODE" != "bench" ]]; then
    echo "--bench-filter only makes sense with --bench" >&2
    exit 2
fi

PHASE_NAMES=()
PHASE_SECS=()

phase() {
    local name="$1"
    shift
    echo "== $name: $* =="
    local t0 t1
    t0=$(date +%s.%N)
    "$@"
    t1=$(date +%s.%N)
    PHASE_NAMES+=("$name")
    PHASE_SECS+=("$(awk -v a="$t0" -v b="$t1" 'BEGIN { printf "%.1f", b - a }')")
}

summary() {
    echo
    for i in "${!PHASE_NAMES[@]}"; do
        printf 'ci-phase %-12s %8ss\n' "${PHASE_NAMES[$i]}" "${PHASE_SECS[$i]}"
    done
}

if [[ "$MODE" == "bench" ]]; then
    # Bench trajectory: run every [[bench]] target in smoke mode, collect
    # per-bench mean/p50/p99 + Melem/s, and assemble BENCH_<N>.json at the
    # repo root (N = current PR sequence number; bump when seeding anew).
    BENCH_OUT="BENCH_10.json"
    JSON_DIR="target/bench-json"
    mkdir -p "$JSON_DIR"
    BENCHES=(coding pipeline runtime paper_tables)
    BENCH_T0=$(date +%s.%N)

    # --bench-filter: a target-name match narrows the target list; anything
    # else is forwarded to the bench binaries as a per-name --filter
    RUN_BENCHES=()
    NAME_FILTER=""
    if [[ -n "$BENCH_FILTER" ]]; then
        for t in "${BENCHES[@]}"; do
            [[ "$t" == *"$BENCH_FILTER"* ]] && RUN_BENCHES+=("$t")
        done
        if [[ ${#RUN_BENCHES[@]} -eq 0 ]]; then
            RUN_BENCHES=("${BENCHES[@]}")
            NAME_FILTER="$BENCH_FILTER"
        fi
    else
        RUN_BENCHES=("${BENCHES[@]}")
    fi

    for bench in "${RUN_BENCHES[@]}"; do
        if [[ -n "$NAME_FILTER" ]]; then
            phase "bench-$bench" \
                cargo bench --bench "$bench" -- --smoke \
                --json="$JSON_DIR/$bench.json" --filter="$NAME_FILTER"
        else
            phase "bench-$bench" \
                cargo bench --bench "$bench" -- --smoke --json="$JSON_DIR/$bench.json"
        fi
    done
    BENCH_T1=$(date +%s.%N)
    PHASE_NAMES+=("bench")
    PHASE_SECS+=("$(awk -v a="$BENCH_T0" -v b="$BENCH_T1" 'BEGIN { printf "%.1f", b - a }')")

    if [[ -n "$BENCH_FILTER" ]]; then
        summary
        echo "ci.sh: filtered bench run ($BENCH_FILTER) — no trajectory point written"
        exit 0
    fi

    {
        printf '{\n  "schema": "tempo-bench-v1",\n  "mode": "smoke",\n  "benches": {\n'
        first=1
        for bench in "${BENCHES[@]}"; do
            [[ "$first" -eq 0 ]] && printf ',\n'
            first=0
            # each file already holds a JSON array; embed it verbatim
            printf '    "%s": ' "$bench"
            cat "$JSON_DIR/$bench.json"
        done
        printf '\n  }\n}\n'
    } > "$BENCH_OUT"
    summary
    echo "ci.sh: bench trajectory written to $BENCH_OUT"
    exit 0
fi

if [[ "$MODE" == "full" ]]; then
    phase "fmt" cargo fmt --check
    phase "clippy" cargo clippy --workspace --all-targets -- -D warnings
    phase "doc" env RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace
fi

phase "build" cargo build --release --workspace
phase "test" cargo test -q --workspace

summary
echo "ci.sh: all green"
