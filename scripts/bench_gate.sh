#!/usr/bin/env bash
# Bench-trajectory regression gate (documented in DESIGN.md §3/§8).
#
#   scripts/bench_gate.sh [--tolerance FRAC] [--explain]
#
# Compares the newest BENCH_<N>.json at the repo root against the previous
# comparable point, per bench name, on mean seconds/iteration. A bench
# regresses when it got slower by more than FRAC (default 0.50 — smoke-mode
# numbers on shared CI runners are noisy; tighten as the trajectory grows).
#
# --explain additionally prints the phase-timing summary of any
# `*.metrics.json` registry snapshot sitting at the repo root (written next
# to `--csv` logs when `[trace]` is on — DESIGN.md §12,
# docs/OBSERVABILITY.md), so a regressed bench can be read against where
# the instrumented run actually spent its time. Explain output never
# changes the gate's verdict.
#
# Gating policy: WARN-ONLY until at least 3 comparable points exist, then
# regressions fail the script (exit 1). Points are comparable when they use
# schema tempo-bench-v1 in smoke mode with a non-empty bench set —
# placeholder points (empty "benches") are skipped entirely, so a toolchain-
# less authoring environment cannot poison the trajectory.
set -euo pipefail
cd "$(dirname "$0")/.."

TOLERANCE="${BENCH_GATE_TOLERANCE:-0.50}"
EXPLAIN=0
while [[ $# -gt 0 ]]; do
    case "$1" in
        --tolerance)
            shift
            TOLERANCE="${1:-}"
            [[ -n "$TOLERANCE" ]] || { echo "--tolerance needs a value" >&2; exit 2; }
            ;;
        --explain) EXPLAIN=1 ;;
        *) echo "usage: scripts/bench_gate.sh [--tolerance FRAC] [--explain]" >&2; exit 2 ;;
    esac
    shift
done

if [[ "$EXPLAIN" -eq 1 ]]; then
    python3 - <<'PY'
import glob
import json

snapshots = sorted(glob.glob("*.metrics.json"))
if not snapshots:
    print("bench-gate: --explain: no *.metrics.json snapshot present (run with --trace + --csv to produce one)")
for path in snapshots:
    try:
        with open(path) as f:
            data = json.load(f)
        rows = data["metrics"]
    except Exception as e:  # unreadable snapshots must not break the gate
        print(f"bench-gate: --explain: skipping {path}: unreadable ({e})")
        continue
    print(f"bench-gate: --explain: phase timings from {path}")
    phases = [r for r in rows if r.get("kind") == "histogram" and ".phase." in r.get("name", "")]
    if not phases:
        print("  (snapshot has no phase histograms)")
        continue
    for r in phases:
        count = r.get("count") or 0
        total = r.get("value") or 0.0
        mean = total / count if count else 0.0
        print(f"  {r['name']:<40} {count:>8} laps  mean {mean:.6f}s  total {total:.3f}s")
PY
fi

TOLERANCE="$TOLERANCE" python3 - <<'PY'
import glob
import json
import os
import re
import sys

tolerance = float(os.environ["TOLERANCE"])

def point_number(path):
    m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(path))
    return int(m.group(1)) if m else None

points = []
numbered = [p for p in glob.glob("BENCH_*.json") if point_number(p) is not None]
for path in sorted(numbered, key=point_number):
    n = point_number(path)
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench-gate: skipping {path}: unreadable ({e})")
        continue
    if data.get("schema") != "tempo-bench-v1" or data.get("mode") != "smoke":
        print(f"bench-gate: skipping {path}: not a comparable smoke point")
        continue
    benches = data.get("benches") or {}
    flat = {}
    for target, rows in benches.items():
        for row in rows or []:
            # malformed rows (schema drift, hand edits) are skipped, never
            # crash the gate — the warn-only promise must hold
            if not isinstance(row, dict) or "name" not in row:
                print(f"bench-gate: {path}: skipping malformed row in {target}")
                continue
            flat[f"{target}::{row['name']}"] = row
    if not flat:
        print(f"bench-gate: skipping {path}: empty bench set (placeholder)")
        continue
    points.append((n, path, flat))

if len(points) < 2:
    print(f"bench-gate: {len(points)} comparable point(s) — nothing to compare, OK")
    sys.exit(0)

(prev_n, prev_path, prev), (cur_n, cur_path, cur) = points[-2], points[-1]
warn_only = len(points) < 3
mode = "warn-only" if warn_only else "enforcing"
print(f"bench-gate: {cur_path} vs {prev_path} (tolerance {tolerance:.0%}, {mode})")

regressions = []
for name in sorted(set(prev) & set(cur)):
    a = prev[name].get("mean_secs")
    b = cur[name].get("mean_secs")
    if not isinstance(a, (int, float)) or not isinstance(b, (int, float)) or a <= 0:
        continue
    delta = (b - a) / a
    marker = ""
    if delta > tolerance:
        regressions.append((name, delta))
        marker = "  <-- REGRESSION"
    if abs(delta) > tolerance / 2 or marker:
        print(f"  {name:<60} {a:.3e}s -> {b:.3e}s  ({delta:+.0%}){marker}")
for name in sorted(set(cur) - set(prev)):
    print(f"  {name:<60} new bench (no baseline)")

if not regressions:
    print("bench-gate: no regressions beyond tolerance, OK")
    sys.exit(0)
print(f"bench-gate: {len(regressions)} bench(es) regressed beyond {tolerance:.0%}")
sys.exit(1 if not warn_only else 0)
PY
