#!/usr/bin/env bash
# Commit the CI-produced bench-trajectory point back to the repo when the
# committed copy is still a placeholder.
#
# Why this exists: PR authoring containers have repeatedly had no Rust
# toolchain (see ROADMAP "Bench trajectory"), so BENCH_<N>.json lands as an
# explicitly-marked placeholder and the real numbers only ever existed as a
# CI artifact nobody committed. This script runs in CI on pushes to main,
# right after `scripts/ci.sh --bench` regenerated the file in the worktree:
#
#   * committed copy is a placeholder AND the regenerated file is a real
#     smoke point  ->  commit + push the real point ([skip ci])
#   * committed copy is already real  ->  do nothing (one point per PR;
#     runner noise must not rewrite the trajectory on every push)
#
# Usage: scripts/commit_bench.sh [--explain] [BENCH_N.json]
#                                 (default: BENCH_10.json)
#
# --explain prints the commit/keep/skip decision and exits without touching
# git state — CI runs it on every build so a silently-skipped self-heal
# (the BENCH_5/BENCH_6 failure mode) shows up in the job log.
set -euo pipefail
cd "$(dirname "$0")/.."

EXPLAIN=0
if [[ "${1:-}" == "--explain" ]]; then
    EXPLAIN=1
    shift
fi
OUT="${1:-BENCH_10.json}"

# exit 0 when $1 is a real (comparable) smoke point, 1 otherwise
is_real() {
    python3 - "$1" <<'PY'
import json
import sys

try:
    with open(sys.argv[1]) as f:
        d = json.load(f)
except Exception:
    sys.exit(1)
benches = d.get("benches") or {}
real = (
    d.get("schema") == "tempo-bench-v1"
    and d.get("mode") == "smoke"
    and any(rows for rows in benches.values())
)
sys.exit(0 if real else 1)
PY
}

if [[ ! -f "$OUT" ]]; then
    echo "commit_bench: $OUT not found (run scripts/ci.sh --bench first)"
    exit 0
fi

HEAD_COPY="$(mktemp)"
trap 'rm -f "$HEAD_COPY"' EXIT
if ! git show "HEAD:$OUT" > "$HEAD_COPY" 2>/dev/null; then
    echo '{}' > "$HEAD_COPY"
fi

if is_real "$HEAD_COPY"; then
    echo "commit_bench: committed $OUT is already a real point; leaving the trajectory alone"
    exit 0
fi
if ! is_real "$OUT"; then
    echo "commit_bench: regenerated $OUT is not a comparable smoke point; nothing to commit"
    exit 0
fi
if [[ "$EXPLAIN" -eq 1 ]]; then
    echo "commit_bench: would commit $OUT (placeholder at HEAD, real smoke point in the worktree)"
    exit 0
fi

git config user.name "tempo-ci"
git config user.email "tempo-ci@users.noreply.github.com"
git add "$OUT"
git commit -m "Record first real $OUT bench point from CI [skip ci]"
# tolerate a non-fast-forward race (another merge landed mid-run): the
# committed copy is still a placeholder, so the next main run retries
if git push; then
    echo "commit_bench: pushed real $OUT"
else
    echo "commit_bench: push raced with another merge; the next main run retries"
fi
