"""L2 models: flat-parameter fwd/bwd graphs the Rust coordinator executes.

Every model exposes the same artifact-level contract:

    fwdbwd(w: f32[d], x, y)  -> (loss: f32[], grad: f32[d])
    evaluate(w: f32[d], x, y) -> (loss: f32[], n_correct: f32[])

Parameters travel as a single flat f32 vector `w` so that the Rust side
(model/, coordinator/) never needs per-leaf plumbing: the gradient it feeds
into the compression pipeline is one contiguous d-vector — exactly the
object the paper compresses. Packing/unpacking happens inside the graph.

Model zoo (paper substitution, see DESIGN.md §4):
  * mlp_tiny / mlp_s — MLP classifiers over 32x32x3 synthetic images.
  * cnn_s            — small conv net (the WRN-28-2 stand-in, conv+pool).
  * lm_tiny/lm_small — decoder-only transformer LM over the Markov corpus;
                       lm_small (~0.9M params) is the e2e example model.

The MLP/FFN nonlinearity is the fused Pallas bias+GELU kernel (kernels/gelu.py),
so the L1 kernel lowers into the same HLO artifact as the rest of the model.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.gelu import bias_gelu

# ---------------------------------------------------------------------------
# Flat-parameter packing
# ---------------------------------------------------------------------------


@dataclass
class ParamSpec:
    """Ordered list of named tensors packed into one flat vector."""

    entries: List[Tuple[str, Tuple[int, ...]]] = field(default_factory=list)

    def add(self, name: str, shape: Tuple[int, ...]) -> None:
        self.entries.append((name, tuple(shape)))

    @property
    def dim(self) -> int:
        return sum(int(np.prod(s)) for _, s in self.entries)

    def unpack(self, w: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        out: Dict[str, jnp.ndarray] = {}
        off = 0
        for name, shape in self.entries:
            n = int(np.prod(shape))
            out[name] = jnp.reshape(w[off:off + n], shape)
            off += n
        return out

    def init_flat(self, seed: int) -> np.ndarray:
        """He/Glorot-style init, packed. Deterministic in `seed`; the result
        is written to artifacts/init_<model>.bin for the Rust launcher."""
        rng = np.random.default_rng(seed)
        parts: List[np.ndarray] = []
        for name, shape in self.entries:
            if len(shape) == 1:  # biases, layernorm offsets
                if name.endswith("ln_g") or name.endswith(".g"):
                    parts.append(np.ones(shape, np.float32))
                else:
                    parts.append(np.zeros(shape, np.float32))
            else:
                fan_in = int(np.prod(shape[:-1]))
                std = math.sqrt(2.0 / max(fan_in, 1))
                if name.startswith("emb") or name.startswith("pos"):
                    std = 0.02
                parts.append(rng.normal(0.0, std, size=shape).astype(np.float32))
        return np.concatenate([p.ravel() for p in parts])


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy; logits (N, C), y int32 (N,)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - picked)


def n_correct(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))


def layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


# ---------------------------------------------------------------------------
# MLP classifier
# ---------------------------------------------------------------------------


@dataclass
class MlpConfig:
    name: str
    in_dim: int
    hidden: Tuple[int, ...]
    classes: int
    batch: int
    l2: float = 1e-4

    def spec(self) -> ParamSpec:
        s = ParamSpec()
        prev = self.in_dim
        for li, h in enumerate(self.hidden):
            s.add(f"w{li}", (prev, h))
            s.add(f"b{li}", (h,))
            prev = h
        s.add("w_out", (prev, self.classes))
        s.add("b_out", (self.classes,))
        return s

    def logits(self, params: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
        h = jnp.reshape(x, (x.shape[0], self.in_dim))
        for li in range(len(self.hidden)):
            h = bias_gelu(h @ params[f"w{li}"], params[f"b{li}"])
        return h @ params["w_out"] + params["b_out"]

    def loss(self, w: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        params = self.spec().unpack(w)
        reg = 0.5 * self.l2 * jnp.sum(jnp.square(w))
        return softmax_xent(self.logits(params, x), y) + reg

    def metrics(self, w, x, y):
        params = self.spec().unpack(w)
        logits = self.logits(params, x)
        return softmax_xent(logits, y), n_correct(logits, y)

    def example_inputs(self):
        return (jnp.zeros((self.batch, self.in_dim), jnp.float32),
                jnp.zeros((self.batch,), jnp.int32))


# ---------------------------------------------------------------------------
# Small conv net (WRN stand-in)
# ---------------------------------------------------------------------------


@dataclass
class CnnConfig:
    name: str
    hw: int
    in_ch: int
    ch: Tuple[int, ...]
    classes: int
    batch: int
    l2: float = 1e-4

    def spec(self) -> ParamSpec:
        s = ParamSpec()
        prev = self.in_ch
        for li, c in enumerate(self.ch):
            s.add(f"k{li}", (3, 3, prev, c))
            s.add(f"cb{li}", (c,))
            prev = c
        final_hw = self.hw // (2 ** len(self.ch))
        s.add("w_out", (final_hw * final_hw * prev, self.classes))
        s.add("b_out", (self.classes,))
        return s

    def logits(self, params, x):
        b = x.shape[0]
        h = jnp.reshape(x, (b, self.hw, self.hw, self.in_ch))
        for li in range(len(self.ch)):
            h = jax.lax.conv_general_dilated(
                h, params[f"k{li}"], window_strides=(1, 1), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            c = h.shape[-1]
            flat = jnp.reshape(h, (-1, c))
            flat = bias_gelu(flat, params[f"cb{li}"])
            h = jnp.reshape(flat, h.shape)
            h = jax.lax.reduce_window(
                h, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") * 0.25
        h = jnp.reshape(h, (b, -1))
        return h @ params["w_out"] + params["b_out"]

    def loss(self, w, x, y):
        params = self.spec().unpack(w)
        reg = 0.5 * self.l2 * jnp.sum(jnp.square(w))
        return softmax_xent(self.logits(params, x), y) + reg

    def metrics(self, w, x, y):
        params = self.spec().unpack(w)
        logits = self.logits(params, x)
        return softmax_xent(logits, y), n_correct(logits, y)

    def example_inputs(self):
        return (jnp.zeros((self.batch, self.hw * self.hw * self.in_ch), jnp.float32),
                jnp.zeros((self.batch,), jnp.int32))


# ---------------------------------------------------------------------------
# Decoder-only transformer LM
# ---------------------------------------------------------------------------


@dataclass
class LmConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq: int
    d_ff: int
    batch: int
    l2: float = 0.0

    def spec(self) -> ParamSpec:
        s = ParamSpec()
        s.add("emb", (self.vocab, self.d_model))
        s.add("pos", (self.seq, self.d_model))
        for li in range(self.n_layers):
            s.add(f"l{li}.ln1_g", (self.d_model,))
            s.add(f"l{li}.ln1_b", (self.d_model,))
            s.add(f"l{li}.wqkv", (self.d_model, 3 * self.d_model))
            s.add(f"l{li}.wo", (self.d_model, self.d_model))
            s.add(f"l{li}.ln2_g", (self.d_model,))
            s.add(f"l{li}.ln2_b", (self.d_model,))
            s.add(f"l{li}.wff1", (self.d_model, self.d_ff))
            s.add(f"l{li}.bff1", (self.d_ff,))
            s.add(f"l{li}.wff2", (self.d_ff, self.d_model))
        s.add("lnf_g", (self.d_model,))
        s.add("lnf_b", (self.d_model,))
        s.add("w_out", (self.d_model, self.vocab))
        return s

    def logits(self, params, tokens):
        b, t = tokens.shape
        dh = self.d_model // self.n_heads
        h = params["emb"][tokens] + params["pos"][None, :t, :]
        mask = jnp.tril(jnp.ones((t, t), jnp.float32))
        neg = jnp.float32(-1e9)
        for li in range(self.n_layers):
            pre = layer_norm(h, params[f"l{li}.ln1_g"], params[f"l{li}.ln1_b"])
            qkv = pre @ params[f"l{li}.wqkv"]
            q, k, v = jnp.split(qkv, 3, axis=-1)

            def heads(x):
                return jnp.transpose(jnp.reshape(x, (b, t, self.n_heads, dh)), (0, 2, 1, 3))

            q, k, v = heads(q), heads(k), heads(v)
            att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(dh)
            att = jnp.where(mask[None, None, :, :] > 0, att, neg)
            att = jax.nn.softmax(att, axis=-1)
            ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
            ctx = jnp.reshape(jnp.transpose(ctx, (0, 2, 1, 3)), (b, t, self.d_model))
            h = h + ctx @ params[f"l{li}.wo"]

            pre2 = layer_norm(h, params[f"l{li}.ln2_g"], params[f"l{li}.ln2_b"])
            ff = jnp.reshape(pre2, (b * t, self.d_model)) @ params[f"l{li}.wff1"]
            ff = bias_gelu(ff, params[f"l{li}.bff1"])
            ff = jnp.reshape(ff @ params[f"l{li}.wff2"], (b, t, self.d_model))
            h = h + ff
        h = layer_norm(h, params["lnf_g"], params["lnf_b"])
        return h @ params["w_out"]

    def loss(self, w, tokens, targets):
        params = self.spec().unpack(w)
        logits = self.logits(params, tokens)
        flat = jnp.reshape(logits, (-1, self.vocab))
        out = softmax_xent(flat, jnp.reshape(targets, (-1,)))
        if self.l2 > 0:
            out = out + 0.5 * self.l2 * jnp.sum(jnp.square(w))
        return out

    def metrics(self, w, tokens, targets):
        params = self.spec().unpack(w)
        logits = jnp.reshape(self.logits(params, tokens), (-1, self.vocab))
        y = jnp.reshape(targets, (-1,))
        return softmax_xent(logits, y), n_correct(logits, y)

    def example_inputs(self):
        return (jnp.zeros((self.batch, self.seq), jnp.int32),
                jnp.zeros((self.batch, self.seq), jnp.int32))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

MODELS = {
    "mlp_tiny": MlpConfig("mlp_tiny", in_dim=3 * 32 * 32, hidden=(32,), classes=10, batch=32),
    "mlp_s": MlpConfig("mlp_s", in_dim=3 * 32 * 32, hidden=(128, 64), classes=10, batch=64),
    "cnn_s": CnnConfig("cnn_s", hw=32, in_ch=3, ch=(8, 16), classes=10, batch=32),
    "lm_tiny": LmConfig("lm_tiny", vocab=64, d_model=32, n_layers=2, n_heads=2,
                        seq=32, d_ff=64, batch=8),
    "lm_small": LmConfig("lm_small", vocab=256, d_model=128, n_layers=4, n_heads=4,
                         seq=64, d_ff=512, batch=16),
}


def model_input_kind(cfg) -> str:
    return "tokens" if isinstance(cfg, LmConfig) else "image"


def fwdbwd_fn(cfg):
    """(w, x, y) -> (loss, grad) — the artifact the worker hot loop executes."""

    def f(w, x, y):
        loss, grad = jax.value_and_grad(cfg.loss)(w, x, y)
        return loss, grad

    return f


def eval_fn(cfg):
    """(w, x, y) -> (loss, n_correct)."""

    def f(w, x, y):
        return cfg.metrics(w, x, y)

    return f
