"""L2 worker compression-step graphs (the whole Fig. 2 worker box).

Composes the L1 Pallas kernels (fused momentum/EF/prediction front, quantizer
kernels, Est-K state update) into one jit-able function per compression
scheme. Each (scheme, d) pair is lowered by aot.py into a standalone HLO
artifact with the uniform signature

    step(g, v, e, rhat, p, s, tau, lr_ratio, aux)
      -> (utilde, v', e', rhat', p', s', tau')

where every vector is f32[d], `lr_ratio` and `aux` are f32[1] scalars
(`aux` is the Rand-K round seed; other quantizers ignore it). Unused state
vectors pass through unchanged, so the Rust side can treat every scheme
identically. This must match kernels.ref.worker_step bit-for-bit — enforced
by python/tests/test_compress_graph.py and, across the language boundary,
by rust integration tests against the pure-Rust pipeline.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from .kernels import compress_step, estk, quantizers

QUANTIZERS = ("none", "sign", "topk", "topkq", "randk")
PREDICTORS = ("zero", "plin", "estk")


@dataclass(frozen=True)
class Scheme:
    """A point in the paper's design space: quantizer x predictor x EF."""

    quantizer: str
    predictor: str
    ef: bool
    beta: float
    k: int = 0  # Top-K / Top-K-Q budget (absolute count, not fraction)
    randk_prob: float = 0.0

    def __post_init__(self):
        if self.quantizer not in QUANTIZERS:
            raise ValueError(f"unknown quantizer {self.quantizer!r}")
        if self.predictor not in PREDICTORS:
            raise ValueError(f"unknown predictor {self.predictor!r}")
        if self.quantizer in ("topk", "topkq") and self.k <= 0:
            raise ValueError(f"{self.quantizer} needs k > 0")
        if self.predictor == "estk" and self.quantizer != "topk":
            # Paper §IV-C: Est-K is designed for (and only defined with) Top-K.
            raise ValueError("estk predictor requires the topk quantizer")
        if not 0.0 <= self.beta < 1.0:
            raise ValueError("beta must be in [0, 1)")

    @property
    def tag(self) -> str:
        parts = [self.quantizer]
        if self.quantizer in ("topk", "topkq"):
            parts.append(f"k{self.k}")
        if self.quantizer == "randk":
            parts.append(f"p{self.randk_prob:g}".replace(".", "_"))
        parts.append(self.predictor)
        parts.append("ef" if self.ef else "noef")
        parts.append(f"b{self.beta:g}".replace(".", "_"))
        return "_".join(parts)


def build_step(scheme: Scheme):
    """Return the jit-able step(g, v, e, rhat, p, s, tau, lr_ratio, aux) fn."""

    def step(g, v_prev, e_prev, rhat, p, s, tau, lr_ratio, aux):
        lr = jnp.reshape(lr_ratio, ())
        v, u = compress_step.fused_front(
            g, v_prev, e_prev, rhat, lr, beta=scheme.beta, ef=scheme.ef)

        if scheme.quantizer == "none":
            utilde = u
        elif scheme.quantizer == "sign":
            utilde = quantizers.scaled_sign(u)
        elif scheme.quantizer == "topk":
            utilde = quantizers.topk_dense(u, scheme.k)
        elif scheme.quantizer == "topkq":
            utilde = quantizers.topkq(u, k=scheme.k)
        else:  # randk
            seed = jnp.reshape(aux, ()).astype(jnp.uint32)
            utilde = quantizers.randk(u, seed, prob=scheme.randk_prob)

        e, rtilde = compress_step.fused_finish(u, utilde, rhat)

        if scheme.predictor == "zero":
            rhat_next = jnp.zeros_like(rtilde)
            p_next, s_next, tau_next = p, s, tau
        elif scheme.predictor == "plin":
            rhat_next = scheme.beta * rtilde
            p_next, s_next, tau_next = p, s, tau
        else:  # estk
            rhat_next, p_next, s_next, tau_next = estk.estk_update(
                utilde, rhat, p, s, tau, beta=scheme.beta)

        return utilde, v, e, rhat_next, p_next, s_next, tau_next

    return step


def zero_state(d: int):
    """Initial (v, e, rhat, p, s, tau) — all zeros, matching paper Eq. (1) init."""
    z = jnp.zeros((d,), jnp.float32)
    return z, z, z, z, z, z
