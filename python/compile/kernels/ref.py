"""Pure-jnp reference oracle for every L1 kernel and L2 compression graph.

This module is the single source of truth for the paper's algorithms
(Adikari & Draper, "Compressing gradients by exploiting temporal correlation
in momentum-SGD", JSAIT 2021). Everything here is written with plain
`jax.numpy` ops only — no Pallas — so it can be diffed against the Pallas
kernels (python/tests/) and against the pure-Rust pipeline
(rust/src/compress/, via the HLO cross-check integration tests).

Conventions
-----------
* All per-component state vectors are flat f32 of dimension d.
* `tau` (iterations since the master last received a non-zero update for a
  component, paper Alg. 1 / Table III) is carried as f32 for HLO uniformity.
* Quantizers return a *dense* d-vector `utilde`; sparsity is an encoding
  concern handled by the Rust coding layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Eq. (1a)-(1c): momentum + error-feedback + prediction error
# ---------------------------------------------------------------------------


def momentum_step(v_prev: jnp.ndarray, g: jnp.ndarray, beta: float) -> jnp.ndarray:
    """Heavy-ball EMA momentum, paper Eq. (1a): v_t = beta v_{t-1} + (1-beta) g_t."""
    return beta * v_prev + (1.0 - beta) * g


def ef_inject(v: jnp.ndarray, e_prev: jnp.ndarray, lr_ratio, ef: bool) -> jnp.ndarray:
    """Paper Eq. (1b): r_t = v_t + (eta_{t-1}/eta_t) e_{t-1} when the EF switch
    is closed, r_t = v_t otherwise. `lr_ratio` is eta_{t-1}/eta_t."""
    if not ef:
        return v
    return v + lr_ratio * e_prev


def prediction_error(r: jnp.ndarray, rhat: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. (1c): u_t = r_t - rhat_t."""
    return r - rhat


def compress_front(g, v_prev, e_prev, rhat, lr_ratio, *, beta: float, ef: bool):
    """The fused front half of the worker step (Eqs. (1a)-(1c)).

    Returns (v, u). This is exactly what the fused Pallas kernel
    `compress_step.fused_front` computes in one pass.
    """
    v = momentum_step(v_prev, g, beta)
    r = ef_inject(v, e_prev, lr_ratio, ef)
    u = prediction_error(r, rhat)
    return v, u


# ---------------------------------------------------------------------------
# Quantizers Q (Eq. (1d))
# ---------------------------------------------------------------------------


def q_none(u: jnp.ndarray) -> jnp.ndarray:
    """Identity quantizer — the uncompressed 32-bit baseline."""
    return u


def q_scaled_sign(u: jnp.ndarray) -> jnp.ndarray:
    """Scaled-sign [Bernstein et al. 2018]: utilde = mean(|u|) * sign(u)."""
    a = jnp.mean(jnp.abs(u))
    return a * jnp.sign(u)


def q_topk(u: jnp.ndarray, k: int) -> jnp.ndarray:
    """Top-K sparsification: keep exactly the K components largest in |.|.

    Tie-break matches `jax.lax.top_k` (stable: lower index wins), which the
    Rust implementation mirrors (sort by (|v| desc, idx asc)).
    """
    _, idx = jax.lax.top_k(jnp.abs(u), k)
    return jnp.zeros_like(u).at[idx].set(u[idx])


def q_topkq(u: jnp.ndarray, k: int) -> jnp.ndarray:
    """Top-K-Q [Dryden et al. 2016]: Top-K, then the surviving positives are
    reconstructed to a single point a+ (mean of surviving positives) and the
    negatives to -a- (mean magnitude of surviving negatives)."""
    kept = q_topk(u, k)
    pos = kept > 0.0
    neg = kept < 0.0
    npos = jnp.sum(pos)
    nneg = jnp.sum(neg)
    a_pos = jnp.where(npos > 0, jnp.sum(jnp.where(pos, kept, 0.0)) / jnp.maximum(npos, 1), 0.0)
    a_neg = jnp.where(nneg > 0, -jnp.sum(jnp.where(neg, kept, 0.0)) / jnp.maximum(nneg, 1), 0.0)
    return jnp.where(pos, a_pos, 0.0) - jnp.where(neg, a_neg, 0.0)


RANDK_H1 = 0x9E3779B1  # golden-ratio odd constant
RANDK_H2 = 0x85EBCA6B
RANDK_M1 = 0x7FEB352D  # triple32 finalizer constants
RANDK_M2 = 0x846CA68B


def randk_hash(j: jnp.ndarray, seed) -> jnp.ndarray:
    """32-bit mix of (component index, round seed) — triple32-style finalizer.

    Must stay identical to rust `compress::randk::hash32` so master and
    workers derive the same selection mask without sending indices.
    """
    seed_u = jnp.asarray(seed, jnp.uint32)
    key = (j + jnp.uint32(1)) * jnp.uint32(RANDK_H1) + seed_u * jnp.uint32(RANDK_H2)
    key = key ^ (key >> 16)
    key = key * jnp.uint32(RANDK_M1)
    key = key ^ (key >> 15)
    key = key * jnp.uint32(RANDK_M2)
    key = key ^ (key >> 16)
    return key


def randk_keep_mask(d: int, seed, prob: float) -> jnp.ndarray:
    """Bernoulli Rand-K selection mask, identical to rust compress::randk.

    keep iff hash32(j, seed) < prob * 2^32. Shared-seed selection means the
    indices never travel on the wire.
    """
    j = jax.lax.iota(jnp.uint32, d)
    key = randk_hash(j, seed)
    thresh = jnp.uint32(min(int(prob * 4294967296.0), 4294967295))
    return key < thresh


def q_randk(u: jnp.ndarray, seed, prob: float) -> jnp.ndarray:
    """Rand-K (Bernoulli variant): keep each component w.p. prob = K/d."""
    return jnp.where(randk_keep_mask(u.shape[0], seed, prob), u, 0.0)


# ---------------------------------------------------------------------------
# Predictors P (Eq. (1g))
# ---------------------------------------------------------------------------


def p_zero(rtilde: jnp.ndarray) -> jnp.ndarray:
    """No prediction: rhat_{t+1} = 0 (removes the blue blocks in Fig. 2)."""
    return jnp.zeros_like(rtilde)


def p_lin(rtilde: jnp.ndarray, beta: float) -> jnp.ndarray:
    """P_Lin, paper Eq. (4): rhat_{t+1} = beta * rtilde_t (DPCM first-order)."""
    return beta * rtilde


def estk_update(utilde, rhat, p, s, tau, *, beta: float):
    """Est-K predictor state update, paper Alg. 1 (reconstructed from Table III).

    Per-component state:
      p   — last estimate of the momentum (time-average between peaks)
      s   — sum of predictions issued since the last received update
      tau — iterations since the last received update
    On receiving a non-zero utilde[k] (k in the Top-K set J_t):
      p'    = (s + utilde[k]) / (tau + 1)
      tau'  = 0
      rhat' = beta * p'
      s'    = rhat'
    Otherwise:
      tau'  = tau + 1
      rhat' = beta * rhat
      s'    = s + rhat'

    Returns (rhat_next, p_next, s_next, tau_next).
    """
    hit = utilde != 0.0
    p_new = (s + utilde) / (tau + 1.0)
    rhat_hit = beta * p_new
    rhat_miss = beta * rhat
    rhat_next = jnp.where(hit, rhat_hit, rhat_miss)
    p_next = jnp.where(hit, p_new, p)
    s_next = jnp.where(hit, rhat_hit, s + rhat_miss)
    tau_next = jnp.where(hit, 0.0, tau + 1.0)
    return rhat_next, p_next, s_next, tau_next


# ---------------------------------------------------------------------------
# Full worker step (the whole Fig. 2 worker box)
# ---------------------------------------------------------------------------


def worker_step(
    g,
    v_prev,
    e_prev,
    rhat,
    p,
    s,
    tau,
    lr_ratio,
    *,
    beta: float,
    ef: bool,
    quantizer: str,
    predictor: str,
    k: int = 0,
    randk_prob: float = 0.0,
    randk_seed=0,
):
    """One full worker iteration of paper Eq. (1), any (Q, P, EF) combination.

    Returns (utilde, v, e, rhat_next, p_next, s_next, tau_next).
    `utilde` is the dense quantizer output the encoder serializes; `rtilde`
    (what the master reconstructs) is `utilde + rhat`.
    """
    v, u = compress_front(g, v_prev, e_prev, rhat, lr_ratio, beta=beta, ef=ef)

    if quantizer == "none":
        utilde = q_none(u)
    elif quantizer == "sign":
        utilde = q_scaled_sign(u)
    elif quantizer == "topk":
        utilde = q_topk(u, k)
    elif quantizer == "topkq":
        utilde = q_topkq(u, k)
    elif quantizer == "randk":
        utilde = q_randk(u, randk_seed, randk_prob)
    else:  # pragma: no cover - guarded by aot config validation
        raise ValueError(f"unknown quantizer {quantizer!r}")

    e = u - utilde  # Eq. (1e)
    rtilde = utilde + rhat  # Eq. (1f)

    if predictor == "zero":
        rhat_next = p_zero(rtilde)
        p_next, s_next, tau_next = p, s, tau
    elif predictor == "plin":
        rhat_next = p_lin(rtilde, beta)
        p_next, s_next, tau_next = p, s, tau
    elif predictor == "estk":
        rhat_next, p_next, s_next, tau_next = estk_update(
            utilde, rhat, p, s, tau, beta=beta
        )
    else:  # pragma: no cover
        raise ValueError(f"unknown predictor {predictor!r}")

    return utilde, v, e, rhat_next, p_next, s_next, tau_next


def master_reconstruct(utilde, rhat, *, beta: float, predictor: str, p=None, s=None, tau=None):
    """Master-side decode chain for one worker: rtilde = utilde + rhat, then
    the same predictor update as the worker (keeps the two in bit-exact sync)."""
    rtilde = utilde + rhat
    if predictor == "zero":
        return rtilde, p_zero(rtilde), p, s, tau
    if predictor == "plin":
        return rtilde, p_lin(rtilde, beta), p, s, tau
    if predictor == "estk":
        rhat_next, p_next, s_next, tau_next = estk_update(utilde, rhat, p, s, tau, beta=beta)
        return rtilde, rhat_next, p_next, s_next, tau_next
    raise ValueError(f"unknown predictor {predictor!r}")


# ---------------------------------------------------------------------------
# Model-side kernel reference: fused bias + GELU (tanh approximation)
# ---------------------------------------------------------------------------

GELU_C = 0.7978845608028654  # sqrt(2/pi)
GELU_A = 0.044715


def gelu_ref(x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """y = gelu(x + b), tanh approximation (matches jax.nn.gelu approximate=True)."""
    z = x + b
    inner = GELU_C * (z + GELU_A * z * z * z)
    return 0.5 * z * (1.0 + jnp.tanh(inner))


def gelu_grad_ref(x: jnp.ndarray, b: jnp.ndarray, dy: jnp.ndarray) -> jnp.ndarray:
    """dz for y = gelu(z), z = x + b. db is dz summed over batch by the caller."""
    z = x + b
    inner = GELU_C * (z + GELU_A * z * z * z)
    t = jnp.tanh(inner)
    sech2 = 1.0 - t * t
    dinner = GELU_C * (1.0 + 3.0 * GELU_A * z * z)
    dgelu = 0.5 * (1.0 + t) + 0.5 * z * sech2 * dinner
    return dy * dgelu
