"""L1 Pallas kernel: Est-K predictor state update (paper Alg. 1).

Fully elementwise over the d components, so it fuses into a single pass:
per component, on a received non-zero utilde the momentum estimate p is
refreshed to the time-average (s + utilde)/(tau+1) and the prediction chain
restarts at beta*p; otherwise the chain decays geometrically and the issued
prediction accumulates into s. See DESIGN.md §2 and ref.estk_update for the
state-machine derivation from paper Table III.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import blocks


def _estk_kernel(ut_ref, rhat_ref, p_ref, s_ref, tau_ref,
                 rhat_out, p_out, s_out, tau_out, *, beta):
    ut = ut_ref[...]
    rhat = rhat_ref[...]
    p = p_ref[...]
    s = s_ref[...]
    tau = tau_ref[...]

    hit = ut != 0.0
    p_new = (s + ut) / (tau + 1.0)
    rhat_hit = beta * p_new
    rhat_miss = beta * rhat

    rhat_out[...] = jnp.where(hit, rhat_hit, rhat_miss)
    p_out[...] = jnp.where(hit, p_new, p)
    s_out[...] = jnp.where(hit, rhat_hit, s + rhat_miss)
    tau_out[...] = jnp.where(hit, 0.0, tau + 1.0)


@functools.partial(jax.jit, static_argnames=("beta", "block"))
def estk_update(utilde, rhat, p, s, tau, *, beta: float,
                block: int = blocks.LANE_BLOCK):
    """One Est-K state transition. Returns (rhat_next, p_next, s_next, tau_next).

    Matches ref.estk_update exactly. Note: padded lanes follow the miss
    branch with all-zero state, so they stay zero except tau, which counts
    up and is sliced away.
    """
    d = utilde.shape[0]
    args = [blocks.pad_to_block(x, block) for x in (utilde, rhat, p, s, tau)]
    grid = blocks.grid_for(d, block)
    shape = jax.ShapeDtypeStruct(args[0].shape, jnp.float32)
    kernel = functools.partial(_estk_kernel, beta=beta)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blocks.vec_spec(block)] * 5,
        out_specs=[blocks.vec_spec(block)] * 4,
        out_shape=[shape] * 4,
        interpret=blocks.INTERPRET,
    )(*args)
    return tuple(o[:d] for o in outs)
