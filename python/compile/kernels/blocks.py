"""Block/tiling helpers shared by the Pallas kernels.

All compression kernels operate on flat f32 vectors of dimension d. We tile
d into 1-D blocks of `LANE_BLOCK` components; the L2 wrappers zero-pad the
inputs up to a block multiple and slice the outputs back. Zero padding is
algebraically safe for every kernel here (all state is zero at the padded
positions, all ops map 0 -> 0, and reductions are sums of |.|).

On a real TPU each block is one HBM->VMEM DMA tile; 4096 f32 lanes = 16 KiB
per operand, far below VMEM, leaving room for the ~9 operands the fused
step streams plus double buffering (see DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
from jax.experimental import pallas as pl

# 4096 f32 = 16 KiB per operand per block.
LANE_BLOCK = 4096

# Pallas on CPU must run in interpret mode: real-TPU lowering emits a Mosaic
# custom-call the CPU PJRT plugin cannot execute.
INTERPRET = True


def padded_len(d: int, block: int = LANE_BLOCK) -> int:
    """Smallest multiple of `block` that is >= d."""
    return ((d + block - 1) // block) * block


def pad_to_block(x: jnp.ndarray, block: int = LANE_BLOCK) -> jnp.ndarray:
    """Zero-pad a flat vector up to a block multiple."""
    d = x.shape[0]
    pad = padded_len(d, block) - d
    if pad == 0:
        return x
    return jnp.pad(x, (0, pad))


def vec_spec(block: int = LANE_BLOCK) -> pl.BlockSpec:
    """BlockSpec for a flat vector tiled into 1-D blocks."""
    return pl.BlockSpec((block,), lambda i: (i,))


def scalar_spec() -> pl.BlockSpec:
    """BlockSpec for a (1,)-shaped scalar broadcast to every block."""
    return pl.BlockSpec((1,), lambda i: (0,))


@functools.lru_cache(maxsize=None)
def grid_for(d: int, block: int = LANE_BLOCK) -> tuple:
    return (padded_len(d, block) // block,)
