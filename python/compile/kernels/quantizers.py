"""L1 Pallas kernels for the quantizers Q (paper Eq. (1d)).

Scaled-sign needs a global reduction (mean |u|); it is implemented as the
classic two-phase pattern: phase 1 computes one partial |.|-sum per block,
phase 2 applies utilde = a * sign(u) with the combined scalar. The tiny
combine between phases is plain jnp (it touches `nblocks` floats, not d).

Top-K *selection* is not elementwise and stays at L2 (`jax.lax.top_k`), but
the mask application / Top-K-Q two-point reconstruction / Rand-K hash are
elementwise Pallas kernels here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import blocks
from .ref import randk_hash


# ---------------------------------------------------------------------------
# Scaled-sign
# ---------------------------------------------------------------------------


def _absum_kernel(u_ref, out_ref):
    out_ref[0] = jnp.sum(jnp.abs(u_ref[...]))


def _sign_apply_kernel(a_ref, u_ref, out_ref):
    u = u_ref[...]
    out_ref[...] = a_ref[0] * jnp.sign(u)


@functools.partial(jax.jit, static_argnames=("block",))
def scaled_sign(u, *, block: int = blocks.LANE_BLOCK):
    """utilde = mean(|u|) * sign(u). Matches ref.q_scaled_sign."""
    d = u.shape[0]
    up = blocks.pad_to_block(u, block)
    grid = blocks.grid_for(d, block)
    partials = pl.pallas_call(
        _absum_kernel,
        grid=grid,
        in_specs=[blocks.vec_spec(block)],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid[0],), jnp.float32),
        interpret=blocks.INTERPRET,
    )(up)
    # Match the reference jnp.mean(|u|) exactly: sum over the true d lanes
    # (padding contributes zero), divide once.
    a = jnp.reshape(jnp.sum(partials) / jnp.float32(d), (1,))
    out = pl.pallas_call(
        _sign_apply_kernel,
        grid=grid,
        in_specs=[blocks.scalar_spec(), blocks.vec_spec(block)],
        out_specs=blocks.vec_spec(block),
        out_shape=jax.ShapeDtypeStruct(up.shape, jnp.float32),
        interpret=blocks.INTERPRET,
    )(a, up)
    return out[:d]


# ---------------------------------------------------------------------------
# Top-K family: selection at L2, masking / reconstruction in Pallas
# ---------------------------------------------------------------------------


def _threshold_mask_kernel(thr_ref, u_ref, out_ref):
    u = u_ref[...]
    out_ref[...] = jnp.where(jnp.abs(u) >= thr_ref[0], u, 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def threshold_sparsify(u, thr, *, block: int = blocks.LANE_BLOCK):
    """Keep components with |u| >= thr (approximate Top-K given a threshold).

    Used by the `topk-approx` ablation: the threshold from iteration t-1 is
    reused at t, trading exact K for a selection pass that is fully fused.
    """
    d = u.shape[0]
    up = blocks.pad_to_block(u, block)
    t = jnp.reshape(jnp.asarray(thr, jnp.float32), (1,))
    grid = blocks.grid_for(d, block)
    out = pl.pallas_call(
        _threshold_mask_kernel,
        grid=grid,
        in_specs=[blocks.scalar_spec(), blocks.vec_spec(block)],
        out_specs=blocks.vec_spec(block),
        out_shape=jax.ShapeDtypeStruct(up.shape, jnp.float32),
        interpret=blocks.INTERPRET,
    )(t, up)
    return out[:d]


def topk_dense(u, k: int):
    """Exact Top-K (dense output) — selection via a lexicographic sort.

    NOT `lax.top_k`: jax ≥ 0.7 lowers that to the `topk(..., largest=true)`
    HLO op, which the xla_extension 0.5.1 text parser rejects. A two-key
    `lax.sort` over (−|u|, index) lowers to a plain HLO `sort` and encodes
    the same tie-break (lower index wins, matching rust compress::topk):
    keep component i iff (−|u_i|, i) ≤ (−|u|, idx) of the K-th sorted entry.
    """
    d = u.shape[0]
    k = min(k, d)
    neg_mag = -jnp.abs(u)
    idx = jax.lax.iota(jnp.int32, d)
    sorted_mag, sorted_idx = jax.lax.sort((neg_mag, idx), num_keys=2)
    thr_mag = sorted_mag[k - 1]
    thr_idx = sorted_idx[k - 1]
    keep = (neg_mag < thr_mag) | ((neg_mag == thr_mag) & (idx <= thr_idx))
    return jnp.where(keep, u, 0.0)


def _two_point_kernel(apos_ref, aneg_ref, kept_ref, out_ref):
    kept = kept_ref[...]
    pos = kept > 0.0
    neg = kept < 0.0
    out_ref[...] = jnp.where(pos, apos_ref[0], 0.0) - jnp.where(neg, aneg_ref[0], 0.0)


def _pos_neg_sums_kernel(kept_ref, out_ref):
    kept = kept_ref[...]
    pos = kept > 0.0
    neg = kept < 0.0
    out_ref[0] = jnp.sum(jnp.where(pos, kept, 0.0))
    out_ref[1] = jnp.sum(jnp.where(pos, 1.0, 0.0))
    out_ref[2] = jnp.sum(jnp.where(neg, -kept, 0.0))
    out_ref[3] = jnp.sum(jnp.where(neg, 1.0, 0.0))


@functools.partial(jax.jit, static_argnames=("k", "block"))
def topkq(u, *, k: int, block: int = blocks.LANE_BLOCK):
    """Top-K-Q: Top-K then two-point (a+, -a-) reconstruction.

    Matches ref.q_topkq. Phase 1 (Pallas): per-block pos/neg sums+counts over
    the kept vector; combine; phase 2 (Pallas): write the two-point values.
    """
    d = u.shape[0]
    kept = topk_dense(u, k)
    kp = blocks.pad_to_block(kept, block)
    grid = blocks.grid_for(d, block)
    partials = pl.pallas_call(
        _pos_neg_sums_kernel,
        grid=grid,
        in_specs=[blocks.vec_spec(block)],
        out_specs=pl.BlockSpec((4,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((4 * grid[0],), jnp.float32),
        interpret=blocks.INTERPRET,
    )(kp)
    part = jnp.reshape(partials, (grid[0], 4))
    pos_sum, npos, neg_sum, nneg = (part[:, 0].sum(), part[:, 1].sum(),
                                    part[:, 2].sum(), part[:, 3].sum())
    a_pos = jnp.where(npos > 0, pos_sum / jnp.maximum(npos, 1.0), 0.0)
    a_neg = jnp.where(nneg > 0, neg_sum / jnp.maximum(nneg, 1.0), 0.0)
    out = pl.pallas_call(
        _two_point_kernel,
        grid=grid,
        in_specs=[blocks.scalar_spec(), blocks.scalar_spec(), blocks.vec_spec(block)],
        out_specs=blocks.vec_spec(block),
        out_shape=jax.ShapeDtypeStruct(kp.shape, jnp.float32),
        interpret=blocks.INTERPRET,
    )(jnp.reshape(a_pos, (1,)), jnp.reshape(a_neg, (1,)), kp)
    return out[:d]


# ---------------------------------------------------------------------------
# Rand-K (Bernoulli, shared-seed LCG hash)
# ---------------------------------------------------------------------------


def _randk_kernel(seed_ref, u_ref, out_ref, *, thresh, block):
    i = pl.program_id(0)
    base = jnp.asarray(i * block, jnp.uint32)
    j = jax.lax.iota(jnp.uint32, block) + base
    key = randk_hash(j, seed_ref[0])
    keep = key < jnp.uint32(thresh)
    out_ref[...] = jnp.where(keep, u_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("prob", "block"))
def randk(u, seed, *, prob: float, block: int = blocks.LANE_BLOCK):
    """Bernoulli Rand-K with the shared-seed LCG hash (matches ref.q_randk)."""
    d = u.shape[0]
    up = blocks.pad_to_block(u, block)
    grid = blocks.grid_for(d, block)
    thresh = min(int(prob * 4294967296.0), 4294967295)
    kernel = functools.partial(_randk_kernel, thresh=thresh, block=block)
    seed_arr = jnp.reshape(jnp.asarray(seed, jnp.uint32), (1,))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blocks.scalar_spec(), blocks.vec_spec(block)],
        out_specs=blocks.vec_spec(block),
        out_shape=jax.ShapeDtypeStruct(up.shape, jnp.float32),
        interpret=blocks.INTERPRET,
    )(seed_arr, up)
    return out[:d]
