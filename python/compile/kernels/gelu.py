"""L1 Pallas kernel: fused bias + GELU (tanh approx) with a custom VJP.

This is the model-side hot-spot kernel: every MLP/FFN block in the L2 models
calls `bias_gelu(x, b)` so that both the forward and the backward pass run
as fused single-pass Pallas kernels instead of the ~8-op unfused chain XLA
would otherwise stream through HBM. The custom VJP is required because
interpret-mode `pallas_call` is not differentiable by itself.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import blocks
from .ref import GELU_A, GELU_C

ROW_BLOCK = 8


def _fwd_kernel(x_ref, b_ref, y_ref):
    z = x_ref[...] + b_ref[...]
    inner = GELU_C * (z + GELU_A * z * z * z)
    y_ref[...] = 0.5 * z * (1.0 + jnp.tanh(inner))


def _bwd_kernel(x_ref, b_ref, dy_ref, dz_ref):
    z = x_ref[...] + b_ref[...]
    inner = GELU_C * (z + GELU_A * z * z * z)
    t = jnp.tanh(inner)
    sech2 = 1.0 - t * t
    dinner = GELU_C * (1.0 + 3.0 * GELU_A * z * z)
    dgelu = 0.5 * (1.0 + t) + 0.5 * z * sech2 * dinner
    dz_ref[...] = dy_ref[...] * dgelu


def _row_grid(n_rows: int) -> tuple:
    return ((n_rows + ROW_BLOCK - 1) // ROW_BLOCK,)


def _pad_rows(x: jnp.ndarray) -> jnp.ndarray:
    pad = (-x.shape[0]) % ROW_BLOCK
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad), (0, 0)))


def _mat_spec(f: int) -> pl.BlockSpec:
    return pl.BlockSpec((ROW_BLOCK, f), lambda i: (i, 0))


def _bias_spec(f: int) -> pl.BlockSpec:
    return pl.BlockSpec((f,), lambda i: (0,))


@jax.custom_vjp
def bias_gelu(x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """y = gelu(x + b) for x: (B, F), b: (F,). Fused Pallas fwd and bwd."""
    return _bias_gelu_fwd_impl(x, b)


@functools.partial(jax.jit)
def _bias_gelu_fwd_impl(x, b):
    n, f = x.shape
    xp = _pad_rows(x)
    y = pl.pallas_call(
        _fwd_kernel,
        grid=_row_grid(n),
        in_specs=[_mat_spec(f), _bias_spec(f)],
        out_specs=_mat_spec(f),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=blocks.INTERPRET,
    )(xp, b)
    return y[:n]


@functools.partial(jax.jit)
def _bias_gelu_bwd_impl(x, b, dy):
    n, f = x.shape
    xp = _pad_rows(x)
    dyp = _pad_rows(dy)
    dz = pl.pallas_call(
        _bwd_kernel,
        grid=_row_grid(n),
        in_specs=[_mat_spec(f), _bias_spec(f), _mat_spec(f)],
        out_specs=_mat_spec(f),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=blocks.INTERPRET,
    )(xp, b, dyp)
    return dz[:n]


def _vjp_fwd(x, b):
    return _bias_gelu_fwd_impl(x, b), (x, b)


def _vjp_bwd(res, dy):
    x, b = res
    dz = _bias_gelu_bwd_impl(x, b, dy)
    return dz, jnp.sum(dz, axis=0)


bias_gelu.defvjp(_vjp_fwd, _vjp_bwd)
