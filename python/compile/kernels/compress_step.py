"""L1 Pallas kernel: the fused front half of the worker compression step.

Computes, in a single pass over each block (paper Eqs. (1a)-(1c)):

    v = beta * v_prev + (1 - beta) * g          # momentum
    r = v + ef * lr_ratio * e_prev              # error-feedback injection
    u = r - rhat                                # prediction error

A naive op-by-op graph streams g, v, e, rhat from HBM once per op (5+ round
trips); the fused kernel streams each operand exactly once and writes v and
u once — the structural win the paper's "negligible computational overhead"
claim (Fig. 1) rests on. beta and the EF switch are compile-time constants
(baked per artifact); lr_ratio = eta_{t-1}/eta_t is a runtime scalar because
the LR schedule steps during training.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import blocks


def _fused_front_kernel(lr_ref, g_ref, v_ref, e_ref, rhat_ref, v_out, u_out, *, beta, ef):
    g = g_ref[...]
    v = beta * v_ref[...] + (1.0 - beta) * g
    if ef:
        r = v + lr_ref[0] * e_ref[...]
    else:
        r = v
    v_out[...] = v
    u_out[...] = r - rhat_ref[...]


@functools.partial(jax.jit, static_argnames=("beta", "ef", "block"))
def fused_front(g, v_prev, e_prev, rhat, lr_ratio, *, beta: float, ef: bool,
                block: int = blocks.LANE_BLOCK):
    """Fused momentum + EF + prediction-error. Returns (v, u), both shape (d,).

    Matches ref.compress_front exactly (same op order per component).
    """
    d = g.shape[0]
    gp = blocks.pad_to_block(g, block)
    vp = blocks.pad_to_block(v_prev, block)
    ep = blocks.pad_to_block(e_prev, block)
    rp = blocks.pad_to_block(rhat, block)
    lr = jnp.reshape(jnp.asarray(lr_ratio, jnp.float32), (1,))
    grid = blocks.grid_for(d, block)
    out_shape = [
        jax.ShapeDtypeStruct(gp.shape, jnp.float32),
        jax.ShapeDtypeStruct(gp.shape, jnp.float32),
    ]
    kernel = functools.partial(_fused_front_kernel, beta=beta, ef=ef)
    v, u = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blocks.scalar_spec()] + [blocks.vec_spec(block)] * 4,
        out_specs=[blocks.vec_spec(block)] * 2,
        out_shape=out_shape,
        interpret=blocks.INTERPRET,
    )(lr, gp, vp, ep, rp)
    return v[:d], u[:d]


def _finish_kernel(u_ref, utilde_ref, rhat_ref, e_out, rtilde_out):
    u = u_ref[...]
    ut = utilde_ref[...]
    e_out[...] = u - ut
    rtilde_out[...] = ut + rhat_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def fused_finish(u, utilde, rhat, *, block: int = blocks.LANE_BLOCK):
    """Fused tail: e = u - utilde (Eq. (1e)) and rtilde = utilde + rhat (Eq. (1f))."""
    d = u.shape[0]
    up = blocks.pad_to_block(u, block)
    utp = blocks.pad_to_block(utilde, block)
    rp = blocks.pad_to_block(rhat, block)
    grid = blocks.grid_for(d, block)
    out_shape = [
        jax.ShapeDtypeStruct(up.shape, jnp.float32),
        jax.ShapeDtypeStruct(up.shape, jnp.float32),
    ]
    e, rtilde = pl.pallas_call(
        _finish_kernel,
        grid=grid,
        in_specs=[blocks.vec_spec(block)] * 3,
        out_specs=[blocks.vec_spec(block)] * 2,
        out_shape=out_shape,
        interpret=blocks.INTERPRET,
    )(up, utp, rp)
    return e[:d], rtilde[:d]
