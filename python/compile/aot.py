"""AOT lowering: every L2 graph -> artifacts/*.hlo.txt + manifest.json.

This is the ONLY Python entry point on the build path (`make artifacts`).
After it runs, the Rust binary is self-contained: rust/src/runtime/ reads
manifest.json, loads the HLO text with HloModuleProto::from_text_file,
compiles on the PJRT CPU client and executes.

HLO *text* (never `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import compress_graph, model
from .compress_graph import Scheme

# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> int:
    # keep_unused=True: the uniform compress-step signature passes state
    # vectors some schemes ignore (e.g. `aux` outside Rand-K); the Rust
    # runtime always supplies all 9 buffers, so the HLO signature must too.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


# ---------------------------------------------------------------------------
# Artifact inventory
# ---------------------------------------------------------------------------

# Models lowered by default. lm_small is the e2e example model (~0.9M params)
# and is skipped by --quick because its bwd graph takes the longest to lower.
DEFAULT_MODELS = ["mlp_tiny", "mlp_s", "cnn_s", "lm_tiny", "lm_small"]
QUICK_MODELS = ["mlp_tiny", "cnn_s", "lm_tiny"]

INIT_SEED = 20210814  # the paper's ISTC presentation date — any constant works

# Small-d compress artifacts used by Rust integration tests (HLO path vs the
# pure-Rust pipeline, bit-for-bit).
TEST_D = 1024
TEST_K = 32
TEST_SCHEMES = [
    Scheme("none", "zero", False, 0.9),
    Scheme("sign", "zero", False, 0.9),
    Scheme("sign", "plin", False, 0.9),
    Scheme("topk", "zero", False, 0.9, k=TEST_K),
    Scheme("topk", "plin", False, 0.9, k=TEST_K),
    Scheme("topkq", "zero", False, 0.9, k=TEST_K),
    Scheme("topkq", "plin", False, 0.9, k=TEST_K),
    Scheme("topk", "zero", True, 0.9, k=TEST_K),
    Scheme("topk", "estk", True, 0.9, k=TEST_K),
    Scheme("topkq", "plin", True, 0.9, k=TEST_K),  # the Fig. 5 divergence case
    Scheme("randk", "zero", False, 0.9, randk_prob=TEST_K / TEST_D),
]


def model_schemes(d: int) -> list:
    """Blessed model-scale schemes (beta = 0.99 as in the paper's Table I)."""
    k_ef = max(1, int(round(2e-3 * d)))
    k_noef = max(1, int(round(1.5e-2 * d)))
    return [
        Scheme("none", "zero", False, 0.99),
        Scheme("sign", "plin", False, 0.99),
        Scheme("topk", "plin", False, 0.99, k=k_noef),
        Scheme("topk", "zero", True, 0.99, k=k_ef),
        Scheme("topk", "estk", True, 0.99, k=k_ef),
    ]


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------


def lower_model(cfg, out_dir: str, manifest: dict) -> None:
    spec = cfg.spec()
    d = spec.dim
    x, y = cfg.example_inputs()
    w = jnp.zeros((d,), jnp.float32)

    fwdbwd_file = f"model_{cfg.name}_fwdbwd.hlo.txt"
    eval_file = f"model_{cfg.name}_eval.hlo.txt"
    init_file = f"init_{cfg.name}.bin"

    t0 = time.time()
    n1 = lower_to_file(model.fwdbwd_fn(cfg), (w, x, y), os.path.join(out_dir, fwdbwd_file))
    n2 = lower_to_file(model.eval_fn(cfg), (w, x, y), os.path.join(out_dir, eval_file))
    init = spec.init_flat(INIT_SEED)
    assert init.shape == (d,)
    init.tofile(os.path.join(out_dir, init_file))
    print(f"  model {cfg.name}: d={d} fwdbwd={n1}B eval={n2}B ({time.time()-t0:.1f}s)")

    entry = {
        "name": cfg.name,
        "d": d,
        "batch": cfg.batch,
        "fwdbwd": fwdbwd_file,
        "eval": eval_file,
        "init": init_file,
        "kind": "lm" if isinstance(cfg, model.LmConfig) else "classifier",
    }
    if isinstance(cfg, model.LmConfig):
        entry.update(vocab=cfg.vocab, seq=cfg.seq)
    else:
        entry.update(in_dim=cfg.in_dim if hasattr(cfg, "in_dim") else cfg.hw * cfg.hw * cfg.in_ch,
                     classes=cfg.classes)
    manifest["models"].append(entry)


def lower_compress(scheme: Scheme, d: int, out_dir: str, manifest: dict) -> None:
    step = compress_graph.build_step(scheme)
    vec = jnp.zeros((d,), jnp.float32)
    one = jnp.zeros((1,), jnp.float32)
    args = (vec,) * 7 + (one, one)
    name = f"compress_d{d}_{scheme.tag}"
    file = f"{name}.hlo.txt"
    t0 = time.time()
    n = lower_to_file(step, args, os.path.join(out_dir, file))
    print(f"  compress {name}: {n}B ({time.time()-t0:.1f}s)")
    manifest["compress"].append({
        "name": name,
        "file": file,
        "d": d,
        "quantizer": scheme.quantizer,
        "predictor": scheme.predictor,
        "ef": scheme.ef,
        "beta": scheme.beta,
        "k": scheme.k,
        "randk_prob": scheme.randk_prob,
    })


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="skip the larger models (CI / smoke builds)")
    ap.add_argument("--models", nargs="*", default=None,
                    help="explicit model list (overrides --quick)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "models": [], "compress": []}

    names = args.models if args.models is not None else (
        QUICK_MODELS if args.quick else DEFAULT_MODELS)

    print(f"[aot] lowering models: {names}")
    for name in names:
        lower_model(model.MODELS[name], args.out_dir, manifest)

    print(f"[aot] lowering test-size compress steps (d={TEST_D})")
    for scheme in TEST_SCHEMES:
        lower_compress(scheme, TEST_D, args.out_dir, manifest)

    print("[aot] lowering model-scale compress steps")
    done = set()
    for name in names:
        d = model.MODELS[name].spec().dim
        if d in done:
            continue
        done.add(d)
        for scheme in model_schemes(d):
            lower_compress(scheme, d, args.out_dir, manifest)

    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {path}: {len(manifest['models'])} models, "
          f"{len(manifest['compress'])} compress artifacts")


if __name__ == "__main__":
    main()
