"""AOT lowering sanity: the HLO text we emit must parse-clean for the
xla_extension 0.5.1 loader (no `topk` op, no pruned params) and the
manifest schema must stay stable for the Rust side."""

import json

import jax
import jax.numpy as jnp

from compile import aot, compress_graph
from compile.compress_graph import Scheme


def lower_text(scheme, d=64):
    step = compress_graph.build_step(scheme)
    vec = jnp.zeros((d,), jnp.float32)
    one = jnp.zeros((1,), jnp.float32)
    args = (vec,) * 7 + (one, one)
    lowered = jax.jit(step, keep_unused=True).lower(*args)
    return aot.to_hlo_text(lowered)


def test_topk_lowering_avoids_topk_hlo_op():
    text = lower_text(Scheme("topk", "estk", True, 0.9, k=8))
    # the 0.5.1 text parser rejects `topk(..., largest=true)`
    assert " topk(" not in text
    assert "sort" in text


def entry_body(text):
    """Lines of the ENTRY computation (the artifact's calling convention)."""
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if "ENTRY" in l)
    body = []
    for l in lines[start + 1:]:
        if l.strip() == "}":
            break
        body.append(l.strip())
    return body


def test_signature_keeps_all_nine_params():
    # even schemes that ignore EF/aux must keep the uniform signature
    for scheme in [
        Scheme("none", "zero", False, 0.9),
        Scheme("sign", "plin", False, 0.9),
        Scheme("randk", "zero", False, 0.9, randk_prob=0.1),
    ]:
        body = entry_body(lower_text(scheme))
        params = [l for l in body if "parameter(" in l]
        assert len(params) == 9, f"{scheme.tag}: {len(params)} params"


def test_outputs_are_seven_tuple():
    body = entry_body(lower_text(Scheme("topk", "estk", True, 0.9, k=4)))
    root = [l for l in body if l.startswith("ROOT")]
    assert root, "no ROOT instruction in ENTRY"
    tuple_type = root[0].split(" tuple(")[0]  # "(f32[64]{0}, ...)" part
    assert tuple_type.count("f32[64]") == 7, root[0]


def test_model_scheme_list_valid():
    # aot.model_schemes must produce valid schemes at any realistic d
    for d in (1024, 98_666, 864_512):
        schemes = aot.model_schemes(d)
        assert len(schemes) >= 5
        tags = [s.tag for s in schemes]
        assert len(set(tags)) == len(tags)


def test_manifest_roundtrips_json(tmp_path):
    manifest = {"version": 1, "models": [], "compress": []}
    scheme = Scheme("topk", "zero", False, 0.9, k=4)
    aot.lower_compress(scheme, 64, str(tmp_path), manifest)
    entry = manifest["compress"][0]
    assert entry["d"] == 64
    assert entry["k"] == 4
    assert (tmp_path / entry["file"]).exists()
    # stable schema for rust/src/model/mod.rs
    assert set(entry) == {
        "name", "file", "d", "quantizer", "predictor", "ef", "beta", "k", "randk_prob",
    }
    json.dumps(manifest)  # serializable
