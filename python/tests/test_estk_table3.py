"""Replay paper Table III — the worked example that defines Est-K (Alg. 1).

The paper only gives Alg. 1 through Table III's trace, so this test pins our
reconstruction of the algorithm to every row of that table: a single
component receives non-zero updates at t=3 and t=6; the predictor must emit
   rhat_4 = beta*p3, rhat_5 = beta^2*p3, rhat_6 = beta^3*p3,
   p3 = (0 + utilde_3)/4,  p6 = ((beta+beta^2+beta^3)*p3 + utilde_6)/3,
and tau must follow 0,1,2,3,0,1,2,0.
"""

import jax.numpy as jnp
import numpy as np

from compile.kernels import ref

BETA = 0.9


def run_trace(utilde_seq):
    """Drive estk_update with a scripted utilde stream for one component."""
    d = 1
    rhat = jnp.zeros(d)
    p = jnp.zeros(d)
    s = jnp.zeros(d)
    tau = jnp.zeros(d)
    hist = []
    for ut in utilde_seq:
        ut_v = jnp.asarray([ut], jnp.float32)
        rhat_next, p, s, tau = ref.estk_update(ut_v, rhat, p, s, tau, beta=BETA)
        hist.append(dict(rhat_in=float(rhat[0]), utilde=ut,
                         rhat_next=float(rhat_next[0]), p=float(p[0]),
                         s=float(s[0]), tau=float(tau[0])))
        rhat = rhat_next
    return hist


def test_table3_trace():
    u3, u6 = 2.5, -1.3  # arbitrary non-zero received values
    hist = run_trace([0.0, 0.0, 0.0, u3, 0.0, 0.0, u6, 0.0])

    # t = 0..2: no update, rhat stays 0, tau counts 1,2,3 after each miss.
    for t in range(3):
        assert hist[t]["rhat_next"] == 0.0
        assert hist[t]["p"] == 0.0
    np.testing.assert_array_equal([h["tau"] for h in hist[:3]], [1.0, 2.0, 3.0])

    # t = 3: hit with tau=3 -> divisor 4 (Table III: p3 = (v0+..+v3)/4 with
    # utilde_3 = r_3 = v0+..+v3 and S=0).
    p3 = (0.0 + u3) / 4.0
    assert abs(hist[3]["p"] - p3) < 1e-6
    assert hist[3]["tau"] == 0.0
    assert abs(hist[3]["rhat_next"] - BETA * p3) < 1e-6
    assert abs(hist[3]["s"] - BETA * p3) < 1e-6

    # t = 4, 5: geometric decay of the prediction chain.
    assert abs(hist[4]["rhat_next"] - BETA**2 * p3) < 1e-6
    assert abs(hist[5]["rhat_next"] - BETA**3 * p3) < 1e-6
    np.testing.assert_allclose(
        hist[5]["s"], (BETA + BETA**2 + BETA**3) * p3, rtol=1e-6)
    np.testing.assert_array_equal([hist[4]["tau"], hist[5]["tau"]], [1.0, 2.0])

    # t = 6: hit with tau=2 -> divisor 3; S = (b+b^2+b^3) p3 (Table III row 6).
    p6 = ((BETA + BETA**2 + BETA**3) * p3 + u6) / 3.0
    assert abs(hist[6]["p"] - p6) < 1e-6
    assert abs(hist[6]["rhat_next"] - BETA * p6) < 1e-6
    assert hist[6]["tau"] == 0.0

    # t = 7: miss again.
    assert abs(hist[7]["rhat_next"] - BETA**2 * p6) < 1e-6
    assert hist[7]["tau"] == 1.0


def test_table3_full_pipeline_consistency():
    """Drive the *whole* worker pipeline (Eq. (1) with EF + Est-K + Top-1 on
    d=2) and assert the e_t bookkeeping of Table III: e_t = r_t - rtilde_t and
    e_t = u_t on misses, e_t = 0 on hits."""
    rng = np.random.default_rng(0)
    d, k, beta = 2, 1, 0.9
    v = jnp.zeros(d); e = jnp.zeros(d); rhat = jnp.zeros(d)
    p = jnp.zeros(d); s = jnp.zeros(d); tau = jnp.zeros(d)
    for t in range(60):
        g = jnp.asarray(rng.normal(size=d), jnp.float32)
        utilde, v, e_new, rhat_n, p, s, tau = ref.worker_step(
            g, v, e, rhat, p, s, tau, 1.0, beta=beta, ef=True,
            quantizer="topk", predictor="estk", k=k)
        hits = np.asarray(utilde) != 0.0
        e_np = np.asarray(e_new)
        # on a hit the transmitted value is exact -> e = 0 there
        assert np.all(np.abs(e_np[hits]) < 1e-6)
        e, rhat = e_new, rhat_n
    # Top-1 sends exactly one component per iteration
    assert int(np.sum(np.asarray(utilde) != 0.0)) == 1
