"""The composed Pallas worker step (compress_graph) vs the jnp oracle.

Threads state through multiple iterations so predictor/EF state transitions
(not just single-shot algebra) are exercised for every scheme family the
paper evaluates.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import compress_graph
from compile.compress_graph import Scheme
from compile.kernels import ref

D = 300
K = 12
ITERS = 8

SCHEMES = [
    Scheme("none", "zero", False, 0.9),
    Scheme("none", "zero", True, 0.9),
    Scheme("sign", "zero", False, 0.9),
    Scheme("sign", "plin", False, 0.99),
    Scheme("topk", "zero", False, 0.9, k=K),
    Scheme("topk", "plin", False, 0.99, k=K),
    Scheme("topkq", "zero", False, 0.9, k=K),
    Scheme("topkq", "plin", False, 0.9, k=K),
    Scheme("topk", "zero", True, 0.9, k=K),
    Scheme("topk", "estk", True, 0.995, k=K),
    Scheme("topkq", "plin", True, 0.9, k=K),
    Scheme("randk", "zero", False, 0.9, randk_prob=0.05),
    Scheme("randk", "plin", True, 0.9, randk_prob=0.05),
]


@pytest.mark.parametrize("scheme", SCHEMES, ids=lambda s: s.tag)
def test_step_matches_ref_over_iterations(scheme):
    rng = np.random.default_rng(hash(scheme.tag) % 2**31)
    step = compress_graph.build_step(scheme)

    v = e = rhat = p = s = tau = jnp.zeros((D,), jnp.float32)
    vr, er, rhr, pr, sr, taur = (jnp.zeros((D,), jnp.float32),) * 6

    for t in range(ITERS):
        g = jnp.asarray(rng.normal(size=D), jnp.float32)
        lr_ratio = 1.0 if t == 0 else float(rng.uniform(0.5, 2.0))
        seed = t + 1

        out = step(g, v, e, rhat, p, s, tau,
                   jnp.asarray([lr_ratio], jnp.float32),
                   jnp.asarray([float(seed)], jnp.float32))
        utilde, v, e, rhat, p, s, tau = out

        wout = ref.worker_step(
            g, vr, er, rhr, pr, sr, taur, lr_ratio,
            beta=scheme.beta, ef=scheme.ef, quantizer=scheme.quantizer,
            predictor=scheme.predictor, k=scheme.k,
            randk_prob=scheme.randk_prob, randk_seed=seed)
        utilde_r, vr, er, rhr, pr, sr, taur = wout

        np.testing.assert_allclose(utilde, utilde_r, atol=3e-5, rtol=3e-5,
                                   err_msg=f"{scheme.tag} t={t} utilde")
        for name, a, b in (("v", v, vr), ("e", e, er), ("rhat", rhat, rhr),
                           ("p", p, pr), ("s", s, sr), ("tau", tau, taur)):
            np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5,
                                       err_msg=f"{scheme.tag} t={t} {name}")


def test_scheme_tag_unique():
    tags = [s.tag for s in SCHEMES]
    assert len(set(tags)) == len(tags)


def test_scheme_validation():
    with pytest.raises(ValueError):
        Scheme("topk", "zero", False, 0.9)  # k missing
    with pytest.raises(ValueError):
        Scheme("sign", "estk", True, 0.9)  # estk requires topk
    with pytest.raises(ValueError):
        Scheme("bogus", "zero", False, 0.9)
    with pytest.raises(ValueError):
        Scheme("none", "bogus", False, 0.9)
    with pytest.raises(ValueError):
        Scheme("none", "zero", False, 1.0)  # beta out of range


def test_none_zero_is_pure_momentum_sgd():
    """With Q=none, P=zero, no EF: utilde == v == the plain momentum vector
    (so the 'baseline' artifact really is uncompressed momentum-SGD)."""
    scheme = Scheme("none", "zero", False, 0.9)
    step = compress_graph.build_step(scheme)
    rng = np.random.default_rng(0)
    v = e = rhat = p = s = tau = jnp.zeros((D,), jnp.float32)
    vm = np.zeros(D, np.float32)
    one = jnp.asarray([1.0], jnp.float32)
    for _ in range(5):
        g = rng.normal(size=D).astype(np.float32)
        utilde, v, e, rhat, p, s, tau = step(
            jnp.asarray(g), v, e, rhat, p, s, tau, one, one)
        vm = 0.9 * vm + 0.1 * g
        np.testing.assert_allclose(utilde, vm, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(e, np.zeros(D), atol=1e-6)


def test_ef_conservation():
    """EF invariant: e_t = u_t - utilde_t and r_t - rtilde_t = e_t (Eq. 8)."""
    scheme = Scheme("topk", "zero", True, 0.9, k=K)
    step = compress_graph.build_step(scheme)
    rng = np.random.default_rng(1)
    v = e = rhat = p = s = tau = jnp.zeros((D,), jnp.float32)
    one = jnp.asarray([1.0], jnp.float32)
    v_prev = np.zeros(D, np.float32)
    e_prev = np.zeros(D, np.float32)
    for _ in range(6):
        g = rng.normal(size=D).astype(np.float32)
        utilde, v, e, rhat, p, s, tau = step(
            jnp.asarray(g), v, e, rhat, p, s, tau, one, one)
        v_np = 0.9 * v_prev + 0.1 * g
        r_np = v_np + e_prev  # lr_ratio = 1
        rtilde = np.asarray(utilde)  # rhat = 0 for P=zero
        np.testing.assert_allclose(np.asarray(e), r_np - rtilde, atol=1e-5)
        v_prev, e_prev = v_np, np.asarray(e)
