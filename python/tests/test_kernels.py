"""L1 Pallas kernels vs the pure-jnp oracle (kernels/ref.py).

hypothesis sweeps shapes, betas, seeds and block sizes (including
non-divisible d so the zero-padding path is exercised). Tolerances: the
elementwise integer-ish paths (Est-K, Top-K-Q reconstruction, Rand-K mask)
must match exactly; float chains allow a few ulps for XLA fusion contraction
differences between eager ref and the compiled Pallas graph.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import compress_step, estk, quantizers, ref
from compile.kernels.gelu import bias_gelu

ATOL = 2e-5
RTOL = 2e-5


def vecs(rng, d, n):
    return [jnp.asarray(rng.normal(size=d), jnp.float32) for _ in range(n)]


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(1, 700),
    beta=st.sampled_from([0.0, 0.5, 0.9, 0.99, 0.995]),
    ef=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
    block=st.sampled_from([64, 256]),
)
def test_fused_front_matches_ref(d, beta, ef, seed, block):
    rng = np.random.default_rng(seed)
    g, v, e, rh = vecs(rng, d, 4)
    lr = float(rng.uniform(0.1, 3.0))
    v2, u2 = compress_step.fused_front(g, v, e, rh, lr, beta=beta, ef=ef, block=block)
    vr, ur = ref.compress_front(g, v, e, rh, lr, beta=beta, ef=ef)
    np.testing.assert_allclose(v2, vr, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(u2, ur, atol=ATOL, rtol=RTOL)


@settings(max_examples=15, deadline=None)
@given(d=st.integers(1, 700), seed=st.integers(0, 2**31 - 1),
       block=st.sampled_from([64, 256]))
def test_fused_finish_matches_ref(d, seed, block):
    rng = np.random.default_rng(seed)
    u, ut, rh = vecs(rng, d, 3)
    e, rtilde = compress_step.fused_finish(u, ut, rh, block=block)
    np.testing.assert_allclose(e, u - ut, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(rtilde, ut + rh, atol=ATOL, rtol=RTOL)


@settings(max_examples=15, deadline=None)
@given(d=st.integers(1, 900), seed=st.integers(0, 2**31 - 1),
       block=st.sampled_from([64, 256]))
def test_scaled_sign_matches_ref(d, seed, block):
    rng = np.random.default_rng(seed)
    (u,) = vecs(rng, d, 1)
    got = quantizers.scaled_sign(u, block=block)
    want = ref.q_scaled_sign(u)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_scaled_sign_zero_vector():
    u = jnp.zeros((100,), jnp.float32)
    np.testing.assert_array_equal(quantizers.scaled_sign(u, block=64), u)


@settings(max_examples=15, deadline=None)
@given(d=st.integers(2, 700), seed=st.integers(0, 2**31 - 1))
def test_topk_dense_matches_ref(d, seed):
    rng = np.random.default_rng(seed)
    (u,) = vecs(rng, d, 1)
    k = int(rng.integers(1, d + 1))
    got = quantizers.topk_dense(u, k)
    want = ref.q_topk(u, k)
    np.testing.assert_array_equal(got, want)
    assert int(jnp.sum(got != 0)) <= k


def test_topk_exactly_k_nonzeros():
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.normal(size=500), jnp.float32)
    for k in (1, 5, 100, 500):
        assert int(jnp.sum(quantizers.topk_dense(u, k) != 0)) == k


def test_topk_keeps_largest_magnitudes():
    u = jnp.asarray([0.1, -5.0, 2.0, -0.2, 3.0], jnp.float32)
    got = quantizers.topk_dense(u, 2)
    np.testing.assert_array_equal(got, jnp.asarray([0, -5.0, 0, 0, 3.0], jnp.float32))


@settings(max_examples=15, deadline=None)
@given(d=st.integers(2, 700), seed=st.integers(0, 2**31 - 1),
       block=st.sampled_from([64, 256]))
def test_topkq_matches_ref(d, seed, block):
    rng = np.random.default_rng(seed)
    (u,) = vecs(rng, d, 1)
    k = int(rng.integers(1, d + 1))
    got = quantizers.topkq(u, k=k, block=block)
    want = ref.q_topkq(u, k)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_topkq_two_reconstruction_points():
    rng = np.random.default_rng(11)
    u = jnp.asarray(rng.normal(size=300), jnp.float32)
    out = np.asarray(quantizers.topkq(u, k=40))
    pos = np.unique(out[out > 0])
    neg = np.unique(out[out < 0])
    assert len(pos) <= 1 and len(neg) <= 1


@settings(max_examples=15, deadline=None)
@given(d=st.integers(1, 900), seed=st.integers(0, 2**31 - 1),
       rseed=st.integers(0, 1000), prob=st.floats(0.0, 1.0),
       block=st.sampled_from([64, 256]))
def test_randk_matches_ref(d, seed, rseed, prob, block):
    rng = np.random.default_rng(seed)
    (u,) = vecs(rng, d, 1)
    got = quantizers.randk(u, rseed, prob=prob, block=block)
    want = ref.q_randk(u, rseed, prob)
    np.testing.assert_array_equal(got, want)


def test_randk_mask_is_seed_deterministic():
    m1 = ref.randk_keep_mask(1000, 42, 0.1)
    m2 = ref.randk_keep_mask(1000, 42, 0.1)
    m3 = ref.randk_keep_mask(1000, 43, 0.1)
    np.testing.assert_array_equal(m1, m2)
    assert bool(jnp.any(m1 != m3))


@settings(max_examples=15, deadline=None)
@given(d=st.integers(1, 700), beta=st.sampled_from([0.5, 0.9, 0.995]),
       seed=st.integers(0, 2**31 - 1), block=st.sampled_from([64, 256]))
def test_estk_update_matches_ref(d, beta, seed, block):
    rng = np.random.default_rng(seed)
    rh, p, s = vecs(rng, d, 3)
    tau = jnp.asarray(rng.integers(0, 50, size=d), jnp.float32)
    # sparse utilde: ~10% nonzero
    ut = jnp.asarray(rng.normal(size=d) * (rng.random(d) < 0.1), jnp.float32)
    got = estk.estk_update(ut, rh, p, s, tau, beta=beta, block=block)
    want = ref.estk_update(ut, rh, p, s, tau, beta=beta)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, atol=ATOL, rtol=RTOL)


@settings(max_examples=10, deadline=None)
@given(d=st.integers(1, 500), seed=st.integers(0, 2**31 - 1),
       block=st.sampled_from([64, 256]))
def test_threshold_sparsify(d, seed, block):
    rng = np.random.default_rng(seed)
    (u,) = vecs(rng, d, 1)
    thr = float(rng.uniform(0.0, 2.0))
    got = quantizers.threshold_sparsify(u, thr, block=block)
    want = jnp.where(jnp.abs(u) >= thr, u, 0.0)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# bias+GELU kernel
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 20), f=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_bias_gelu_forward(b, f, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, f)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=f), jnp.float32)
    got = bias_gelu(x, bias)
    want = ref.gelu_ref(x, bias)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_bias_gelu_matches_jax_nn():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    b = jnp.asarray(rng.normal(size=32), jnp.float32)
    want = jax.nn.gelu(x + b, approximate=True)
    np.testing.assert_allclose(bias_gelu(x, b), want, atol=1e-5, rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(b=st.integers(1, 12), f=st.integers(1, 48), seed=st.integers(0, 2**31 - 1))
def test_bias_gelu_vjp_matches_autodiff_of_ref(b, f, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, f)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=f), jnp.float32)

    def f_kernel(x, bias):
        return jnp.sum(jnp.sin(bias_gelu(x, bias)))

    def f_ref(x, bias):
        return jnp.sum(jnp.sin(ref.gelu_ref(x, bias)))

    gx, gb = jax.grad(f_kernel, argnums=(0, 1))(x, bias)
    rx, rb = jax.grad(f_ref, argnums=(0, 1))(x, bias)
    np.testing.assert_allclose(gx, rx, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(gb, rb, atol=1e-4, rtol=1e-4)


def test_gelu_grad_ref_consistent_with_autodiff():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    b = jnp.asarray(rng.normal(size=16), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    want = jax.vjp(lambda x: ref.gelu_ref(x, b), x)[1](dy)[0]
    got = ref.gelu_grad_ref(x, b, dy)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Quantizer analytic invariants (paper §I-A: delta-compressor properties)
# ---------------------------------------------------------------------------


def test_topk_is_delta_compressor():
    """Top-K satisfies ||x - Q(x)||^2 <= (1 - K/d) ||x||^2 (K/d-compressor)."""
    rng = np.random.default_rng(5)
    for _ in range(20):
        d = int(rng.integers(10, 400))
        k = int(rng.integers(1, d))
        x = jnp.asarray(rng.normal(size=d), jnp.float32)
        q = ref.q_topk(x, k)
        lhs = float(jnp.sum((x - q) ** 2))
        rhs = (1.0 - k / d) * float(jnp.sum(x ** 2))
        assert lhs <= rhs + 1e-4


def test_scaled_sign_is_delta_compressor():
    """Scaled-sign satisfies the 1/d bound: ||x-Q(x)||^2 <= (1 - 1/d)||x||^2
    ... in fact mean-|x| scaling gives ||x-Q||^2 = ||x||^2 - d*a^2."""
    rng = np.random.default_rng(6)
    for _ in range(20):
        d = int(rng.integers(2, 400))
        x = jnp.asarray(rng.normal(size=d), jnp.float32)
        q = ref.q_scaled_sign(x)
        lhs = float(jnp.sum((x - q) ** 2))
        rhs = (1.0 - 1.0 / d) * float(jnp.sum(x ** 2))
        assert lhs <= rhs + 1e-3


def test_sign_quantizer_error_orthogonality():
    """With a = mean|x|: ||x - a sign(x)||^2 = ||x||^2 - 2a*sum|x| + d a^2
    = ||x||^2 - d a^2 (the projection identity)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=1000), jnp.float32)
    a = float(jnp.mean(jnp.abs(x)))
    q = ref.q_scaled_sign(x)
    lhs = float(jnp.sum((x - q) ** 2))
    want = float(jnp.sum(x ** 2)) - 1000 * a * a
    assert abs(lhs - want) < 1e-2
