"""L2 model graphs: shapes, loss sanity, gradient correctness (finite diff),
and the flat-parameter packing round trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import MODELS


def small_mlp():
    return model.MlpConfig("t_mlp", in_dim=12, hidden=(8,), classes=3, batch=4, l2=0.0)


def small_lm():
    return model.LmConfig("t_lm", vocab=11, d_model=8, n_layers=1, n_heads=2,
                          seq=6, d_ff=16, batch=2)


def small_cnn():
    return model.CnnConfig("t_cnn", hw=8, in_ch=1, ch=(2,), classes=3, batch=2, l2=0.0)


def rand_inputs(cfg, rng):
    x, y = cfg.example_inputs()
    if x.dtype == jnp.int32:
        x = jnp.asarray(rng.integers(0, cfg.vocab, size=x.shape), jnp.int32)
        y = jnp.asarray(rng.integers(0, cfg.vocab, size=y.shape), jnp.int32)
    else:
        x = jnp.asarray(rng.normal(size=x.shape), jnp.float32)
        classes = cfg.classes
        y = jnp.asarray(rng.integers(0, classes, size=y.shape), jnp.int32)
    return x, y


@pytest.mark.parametrize("mk", [small_mlp, small_cnn, small_lm])
def test_fwdbwd_shapes_and_finiteness(mk):
    cfg = mk()
    rng = np.random.default_rng(0)
    d = cfg.spec().dim
    w = jnp.asarray(cfg.spec().init_flat(0))
    x, y = rand_inputs(cfg, rng)
    loss, grad = model.fwdbwd_fn(cfg)(w, x, y)
    assert grad.shape == (d,)
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(grad)))
    assert float(jnp.linalg.norm(grad)) > 0


@pytest.mark.parametrize("mk", [small_mlp, small_cnn, small_lm])
def test_grad_matches_finite_differences(mk):
    cfg = mk()
    rng = np.random.default_rng(1)
    w = jnp.asarray(cfg.spec().init_flat(1))
    x, y = rand_inputs(cfg, rng)
    loss_fn = lambda w_: cfg.loss(w_, x, y)
    _, grad = model.fwdbwd_fn(cfg)(w, x, y)
    grad = np.asarray(grad, np.float64)
    eps = 1e-3
    idxs = rng.integers(0, cfg.spec().dim, size=6)
    for i in idxs:
        basis = np.zeros(cfg.spec().dim, np.float32)
        basis[i] = eps
        fp = float(loss_fn(w + jnp.asarray(basis)))
        fm = float(loss_fn(w - jnp.asarray(basis)))
        fd = (fp - fm) / (2 * eps)
        assert abs(fd - grad[i]) < 5e-2 * max(1.0, abs(fd)), (i, fd, grad[i])


def test_eval_counts_bounded():
    cfg = small_mlp()
    rng = np.random.default_rng(2)
    w = jnp.asarray(cfg.spec().init_flat(2))
    x, y = rand_inputs(cfg, rng)
    loss, ncorr = model.eval_fn(cfg)(w, x, y)
    assert 0 <= float(ncorr) <= cfg.batch
    assert np.isfinite(float(loss))


def test_param_spec_pack_unpack_roundtrip():
    cfg = small_lm()
    spec = cfg.spec()
    rng = np.random.default_rng(3)
    w = rng.normal(size=spec.dim).astype(np.float32)
    parts = spec.unpack(jnp.asarray(w))
    # repack in order and compare
    flat = np.concatenate([np.asarray(parts[n]).ravel() for n, _ in spec.entries])
    np.testing.assert_array_equal(flat, w)


def test_init_flat_deterministic_and_scaled():
    cfg = small_mlp()
    spec = cfg.spec()
    a = spec.init_flat(7)
    b = spec.init_flat(7)
    c = spec.init_flat(8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert np.abs(a).max() < 5.0  # He-scaled, no wild values


def test_registry_dims_match_spec():
    for name, cfg in MODELS.items():
        d = cfg.spec().dim
        assert d > 0, name
        # packing covers every entry exactly once
        total = sum(int(np.prod(s)) for _, s in cfg.spec().entries)
        assert total == d


def test_lm_loss_decreases_with_sgd_steps():
    """Five plain-SGD steps on one batch must reduce the training loss —
    catches sign errors in the fwd/bwd plumbing."""
    cfg = small_lm()
    rng = np.random.default_rng(4)
    w = jnp.asarray(cfg.spec().init_flat(4))
    x, y = rand_inputs(cfg, rng)
    f = jax.jit(model.fwdbwd_fn(cfg))
    l0, g = f(w, x, y)
    losses = [float(l0)]
    for _ in range(5):
        w = w - 0.5 * g
        l, g = f(w, x, y)
        losses.append(float(l))
    assert losses[-1] < losses[0]
